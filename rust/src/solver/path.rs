//! Lasso / elastic-net pathwise fitting with hybrid safe-strong screening —
//! **Algorithm 1** of the paper, generalized over all the "Method" rows of
//! its tables:
//!
//! | [`RuleKind`]        | safe set `S`         | optimizer set `H`       | KKT check over |
//! |---------------------|----------------------|-------------------------|----------------|
//! | `BasicPcd`          | all                  | all                     | — (exact)      |
//! | `ActiveCycling`     | all                  | ever-active set         | all \ H        |
//! | `Ssr`               | all                  | SSR strong set          | all \ H        |
//! | `Sedpp`             | SEDPP set            | `S` (safe ⇒ no check)   | —              |
//! | `SsrBedpp`          | BEDPP set            | SSR ∩ S                 | `S \ H`        |
//! | `SsrDome`           | Dome set             | SSR ∩ S                 | `S \ H`        |
//! | `SsrBedppSedpp`     | BEDPP→frozen-SEDPP   | SSR ∩ S                 | `S \ H`        |
//! | `SsrGapSafe`        | dynamic gap-safe set | SSR ∩ S                 | `S \ H`, re-screened |
//!
//! The λ-loop itself lives in the **generic driver**
//! ([`crate::solver::driver::drive`]); this module contributes the
//! quadratic-loss column problem [`GaussianLasso`] (elastic net included
//! via [`Penalty`]) and the thin [`fit_lasso_path`] shims around it.
//!
//! The `z_j = x_jᵀr/n` values are maintained lazily exactly as Algorithm 1
//! prescribes: screening at `λ_k` reuses the values computed during KKT
//! checking at `λ_{k−1}`; only features newly entering the safe set are
//! refreshed (line 4). The safe rule is switched off permanently once it
//! stops discarding (`Flag`, lines 6–8).
//!
//! ## Fused execution (default)
//!
//! With [`PathConfig::fused`] (the default), each λ step issues **one**
//! engine pass where the unfused driver issued three traversals:
//!
//! * screening runs through [`ScanEngine::fused_screen`] — the safe rule
//!   contributes a per-column predicate via
//!   [`crate::screening::SafeRule::plan`] (BEDPP/Dome; sequential rules
//!   screen into the mask first), and the kernel applies the predicate,
//!   refreshes stale `z_j`, and classifies against the SSR threshold per
//!   column;
//! * the post-convergence check runs through [`ScanEngine::fused_kkt`] —
//!   one traversal recomputes `z_j` over `S \ H` and tests KKT. The
//!   unfused driver's separate end-of-step strong-set refresh disappears
//!   entirely: the residual is unchanged until the next λ's screening, so
//!   the fused screen lazily refreshes the strong columns there with
//!   bit-identical values (and the final λ's refresh is never paid).
//!
//! Selections and solutions are bit-identical to the unfused driver
//! (`fused: false`, kept for A/B benchmarking and the equivalence property
//! test in [`crate::prop`]).

use std::sync::Arc;

use crate::data::store::ColumnStore;
use crate::data::Dataset;
use crate::error::{HssrError, Result};
use crate::linalg::{ops, DenseMatrix};
use crate::obs::trace::{self, Span};
use crate::runtime::{native::NativeEngine, ooc, Precision, ScanEngine};
use crate::screening::{make_safe_rule, ssr, PrevSolution, RuleKind, SafeContext, SafeRule};
use crate::serialize::{ByteReader, ByteWriter};
use crate::solver::columns::ColSource;
use crate::solver::driver::{
    apply_rescreen_mask, drive_warm, dynamic_burst_solve, fused_default, fused_epoch_default,
    zero_discarded_units, BurstProblem, DriverConfig, DriverFit, Problem, ScreenStage,
};
use crate::solver::{cd, kkt, lambda::GridKind, Penalty};

pub use crate::solver::driver::{LambdaMetrics, PathError, WarmStart};

/// Configuration for a pathwise fit.
#[derive(Clone, Debug)]
pub struct PathConfig {
    /// Screening strategy (paper "Method").
    pub rule: RuleKind,
    /// Penalty family.
    pub penalty: Penalty,
    /// Number of λ grid points (paper: 100).
    pub n_lambda: usize,
    /// Smallest λ as a fraction of λmax (paper: 0.1).
    pub lambda_min_ratio: f64,
    /// Grid spacing (paper: linear on λ/λmax).
    pub grid: GridKind,
    /// Convergence tolerance on max |Δβ| per cycle.
    pub tol: f64,
    /// Maximum CD cycles per λ (per violation round).
    pub max_iter: usize,
    /// Explicit λ grid (overrides `n_lambda`/`lambda_min_ratio`).
    pub lambdas: Option<Vec<f64>>,
    /// Drive the fused single-pass screening/KKT pipeline (default). The
    /// unfused scan-then-filter driver is retained for benchmarking and
    /// equivalence testing; both select identical feature sets.
    pub fused: bool,
    /// CD epochs between *dynamic* gap-safe re-fires inside the inner
    /// solve (`--rule ssr-gapsafe`); `0` disables the mid-solve prunes
    /// (the per-λ screen and the pre-KKT driver re-screen remain). Ignored
    /// by static rules.
    pub rescreen_every: usize,
    /// Crash-resume checkpoint file (`--checkpoint`): the driver rewrites
    /// it atomically after every λ and resumes from it bit-identically.
    /// `None` disables checkpointing.
    pub checkpoint: Option<std::path::PathBuf>,
    /// Arithmetic precision for the *screening* scans (`--precision` /
    /// `HSSR_PRECISION`). [`Precision::F32`] lets supporting safe rules
    /// prefilter with f32 scans widened by a proven error bound, exactly
    /// confirming boundary columns in f64 — final coefficients are
    /// bit-identical to an all-f64 fit. KKT checks and the inner solver
    /// always run in f64.
    pub precision: Precision,
    /// Fuse the dynamic rule's pre-KKT re-screen with the KKT refresh:
    /// the correlations the rule just scanned are republished into the
    /// lazy `z` cache (the residual is unchanged between the two stages),
    /// so the KKT pass reuses them instead of re-traversing the candidate
    /// columns — one pass per epoch instead of two. `false` keeps the
    /// two-pass flow for A/B equivalence testing (`HSSR_FUSED_EPOCH=0`);
    /// both produce bit-identical paths.
    pub fused_epoch: bool,
}

impl Default for PathConfig {
    fn default() -> Self {
        PathConfig {
            rule: RuleKind::SsrBedpp,
            penalty: Penalty::Lasso,
            n_lambda: 100,
            lambda_min_ratio: 0.1,
            grid: GridKind::Linear,
            tol: 1e-7,
            max_iter: 100_000,
            lambdas: None,
            fused: fused_default(),
            rescreen_every: 10,
            checkpoint: None,
            precision: Precision::from_env(),
            fused_epoch: fused_epoch_default(),
        }
    }
}

impl PathConfig {
    /// Lower to the problem-independent driver configuration.
    fn driver(&self) -> DriverConfig {
        DriverConfig {
            rule: self.rule,
            n_lambda: self.n_lambda,
            lambda_min_ratio: self.lambda_min_ratio,
            grid: self.grid,
            lambdas: self.lambdas.clone(),
            fused: self.fused,
            checkpoint: self.checkpoint.clone(),
        }
    }
}

/// Result of a pathwise fit.
#[derive(Clone, Debug)]
pub struct PathFit {
    /// The λ grid actually used (decreasing).
    pub lambdas: Vec<f64>,
    /// Sparse coefficient vectors, one per λ: `(feature, value)` pairs.
    pub betas: Vec<Vec<(usize, f64)>>,
    /// Per-λ instrumentation.
    pub metrics: Vec<LambdaMetrics>,
    /// Number of features.
    pub p: usize,
    /// λmax computed from the data.
    pub lambda_max: f64,
    /// Wall-clock seconds for the whole path.
    pub seconds: f64,
    /// Strategy used.
    pub rule: RuleKind,
    /// `Some` when the path degraded gracefully: the solver failed at
    /// `error.lambda_index` and the fit holds only the completed λ-prefix.
    pub error: Option<PathError>,
}

impl PathFit {
    /// Number of nonzero coefficients at grid index `k`.
    pub fn nonzero_at(&self, k: usize) -> usize {
        self.betas[k].len()
    }

    /// Densify the coefficient vector at grid index `k`.
    pub fn beta_dense(&self, k: usize) -> Vec<f64> {
        let mut b = vec![0.0; self.p];
        for &(j, v) in &self.betas[k] {
            b[j] = v;
        }
        b
    }

    /// Total columns scanned over the whole path (memory-traffic proxy,
    /// §3.2.3).
    pub fn total_cols_scanned(&self) -> u64 {
        self.metrics.iter().map(|m| m.cols_scanned).sum()
    }

    /// Total KKT checks performed over the path.
    pub fn total_kkt_checks(&self) -> u64 {
        self.metrics.iter().map(|m| m.kkt_checked as u64).sum()
    }

    /// Total violations over the path.
    pub fn total_violations(&self) -> u64 {
        self.metrics.iter().map(|m| m.violations as u64).sum()
    }
}

/// Refresh `z[j] = x_jᵀr/n` over `cols` at the current residual, marking
/// them valid and accounting the scans — the lazy-correlation refresh
/// shared by the column-unit problems (Gaussian and logistic; Algorithm 1
/// lines 4 and 18).
#[allow(clippy::too_many_arguments)]
pub(crate) fn column_refresh(
    engine: &dyn ScanEngine,
    x: &DenseMatrix,
    r: &[f64],
    cols: &[usize],
    z: &mut [f64],
    z_valid: &mut [bool],
    scratch: &mut [f64],
    m: &mut LambdaMetrics,
) -> Result<()> {
    if cols.is_empty() {
        return Ok(());
    }
    engine.scan_subset(x, r, cols, &mut scratch[..cols.len()])?;
    for (s, &j) in cols.iter().enumerate() {
        z[j] = scratch[s];
        z_valid[j] = true;
    }
    m.cols_scanned += cols.len() as u64;
    Ok(())
}

/// One column-unit KKT pass over `survive \ in_strong` with lazy-`z`
/// bookkeeping (Algorithm 1 lines 14–17), shared by the column-unit
/// problems. Fused: one engine traversal recomputes candidate `z` and
/// tests KKT, deliberately NOT refreshing strong columns (the residual is
/// unchanged until the next λ's screening, which refreshes them lazily
/// with bit-identical values — no redundant rescans on violation rounds,
/// and the last λ's refresh is never paid). Unfused: scan-then-filter.
#[allow(clippy::too_many_arguments)]
pub(crate) fn column_kkt(
    engine: &dyn ScanEngine,
    x: &DenseMatrix,
    r: &[f64],
    penalty: Penalty,
    lam: f64,
    fused: bool,
    survive: &[bool],
    in_strong: &[bool],
    z: &mut [f64],
    z_valid: &mut [bool],
    scratch: &mut [f64],
    m: &mut LambdaMetrics,
) -> Result<Vec<usize>> {
    if fused {
        let violates = move |zj: f64| kkt::violates(penalty, lam, zj);
        let fout =
            engine.fused_kkt(x, r, survive, in_strong, &violates, false, z, z_valid)?;
        m.cols_scanned += fout.cols_scanned;
        m.kkt_checked += fout.checked;
        return Ok(fout.violations);
    }
    // `survive.len()`, not `x.ncols()`: store-backed fits pass a
    // zero-column dummy design and the engine serves the real columns.
    let p = survive.len();
    let check: Vec<usize> = (0..p).filter(|&j| survive[j] && !in_strong[j]).collect();
    if check.is_empty() {
        return Ok(Vec::new());
    }
    column_refresh(engine, x, r, &check, z, z_valid, scratch, m)?;
    m.kkt_checked += check.len();
    Ok(kkt::violations(penalty, lam, &check, &scratch[..check.len()]))
}

/// The quadratic-loss column problem (lasso and elastic net) as a
/// [`Problem`] instance: coordinate-descent inner loop, lazy `z = Xᵀr/n`
/// bookkeeping, lasso safe rules, and the scalar KKT test with the
/// elastic-net α scaling.
pub struct GaussianLasso<'a> {
    x: &'a DenseMatrix,
    engine: &'a dyn ScanEngine,
    penalty: Penalty,
    rule: RuleKind,
    tol: f64,
    max_iter: usize,
    rescreen_every: usize,
    fused_epoch: bool,
    ctx: SafeContext,
    safe_rule: Option<Box<dyn SafeRule>>,
    beta: Vec<f64>,
    r: Vec<f64>,
    // z_j = x_jᵀr/n at the most recent residual where it was computed.
    z: Vec<f64>,
    z_valid: Vec<bool>,
    scratch: Vec<f64>,
    // Columns the constructor scanned (store-backed builds only), folded
    // into λ0's metrics so engine counters reconcile with path accounting.
    preamble: u64,
    // Store read failure parked by the infallible `BurstProblem::evict`;
    // `solve` surfaces it after the burst driver returns.
    deferred: Option<HssrError>,
}

/// Build the safe-rule context entirely from a column store: the same
/// `O(np)` precompute as [`SafeContext::build`], every scan served by the
/// store (bit-identical — the store scan is the same per-column
/// reduction). Returns the context plus the columns fetched, which the
/// problem reports as [`Problem::preamble_cols`].
fn store_safe_context(
    store: &ColumnStore,
    penalty: Penalty,
    need_star: bool,
) -> Result<(SafeContext, u64)> {
    let n = store.nrows();
    let p = store.ncols();
    let y = store.y().to_vec();
    let idx: Vec<usize> = (0..p).collect();
    let mut xty = vec![0.0; p];
    store.scan_subset(&y, &idx, &mut xty)?;
    for v in xty.iter_mut() {
        *v *= n as f64;
    }
    let (star, max_abs) = ops::abs_argmax(&xty);
    let lambda_max = max_abs / (penalty.alpha() * n as f64);
    let sign_star = if xty[star] >= 0.0 { 1.0 } else { -1.0 };
    let mut fetched = p as u64;
    let xtx_star = if need_star {
        let star_col = store.with_col(star, |col| col.to_vec())?;
        let mut v = vec![0.0; p];
        store.scan_subset(&star_col, &idx, &mut v)?;
        for w in v.iter_mut() {
            *w *= n as f64;
        }
        fetched += p as u64 + 1;
        v
    } else {
        Vec::new()
    };
    let y_sq = ops::nrm2_sq(&y);
    Ok((
        SafeContext { n, p, y, xty, xtx_star, y_sq, lambda_max, star, sign_star, penalty },
        fetched,
    ))
}

impl<'a> GaussianLasso<'a> {
    /// Build the problem: validate the penalty, run the `O(np)` safe-rule
    /// precompute, start cold at `β = 0`.
    pub fn new(
        ds: &'a Dataset,
        cfg: &PathConfig,
        engine: &'a dyn ScanEngine,
    ) -> Result<Self> {
        cfg.penalty.validate()?;
        let x = &ds.x;
        let n = ds.n();
        let p = ds.p();
        let ctx = SafeContext::build(x, &ds.y, cfg.penalty, cfg.rule.needs_star());
        let z: Vec<f64> = ctx.xty.iter().map(|v| v / n as f64).collect();
        let mut safe_rule = make_safe_rule(cfg.rule);
        if let Some(rule) = safe_rule.as_mut() {
            rule.set_precision(cfg.precision);
        }
        Ok(GaussianLasso {
            x,
            engine,
            penalty: cfg.penalty,
            rule: cfg.rule,
            tol: cfg.tol,
            max_iter: cfg.max_iter,
            rescreen_every: cfg.rescreen_every,
            fused_epoch: cfg.fused_epoch,
            safe_rule,
            beta: vec![0.0; p],
            r: ds.y.clone(),
            z,
            z_valid: vec![true; p],
            scratch: vec![0.0; p],
            preamble: 0,
            deferred: None,
            ctx,
        })
    }

    /// Build the problem directly over the engine's column store — the
    /// serve/CV path, where the design is never materialized in memory.
    /// `x` must be the caller-owned zero-column dummy design
    /// (`DenseMatrix::zeros(n, 0)`): it carries the row count for shape
    /// checks; nothing reads its columns. The safe-rule precompute runs
    /// through the store and is reported via [`Problem::preamble_cols`].
    pub fn from_store(
        x: &'a DenseMatrix,
        cfg: &PathConfig,
        engine: &'a dyn ScanEngine,
    ) -> Result<Self> {
        cfg.penalty.validate()?;
        let store = engine.column_store().ok_or_else(|| {
            HssrError::Config(
                "store-backed fit requires an engine that advertises a column store".into(),
            )
        })?;
        debug_assert_eq!(x.ncols(), 0, "store-backed fits take the zero-column dummy");
        debug_assert_eq!(x.nrows(), store.nrows());
        let (ctx, preamble) = store_safe_context(store, cfg.penalty, cfg.rule.needs_star())?;
        let (n, p) = (ctx.n, ctx.p);
        let z: Vec<f64> = ctx.xty.iter().map(|v| v / n as f64).collect();
        let mut safe_rule = make_safe_rule(cfg.rule);
        if let Some(rule) = safe_rule.as_mut() {
            rule.set_precision(cfg.precision);
        }
        Ok(GaussianLasso {
            x,
            engine,
            penalty: cfg.penalty,
            rule: cfg.rule,
            tol: cfg.tol,
            max_iter: cfg.max_iter,
            rescreen_every: cfg.rescreen_every,
            fused_epoch: cfg.fused_epoch,
            safe_rule,
            beta: vec![0.0; p],
            r: ctx.y.clone(),
            z,
            z_valid: vec![true; p],
            scratch: vec![0.0; p],
            preamble,
            deferred: None,
            ctx,
        })
    }

    /// Whether the attached safe rule is dynamic (gap-safe).
    fn dynamic_rule(&self) -> bool {
        self.safe_rule.as_ref().map(|r| r.dynamic()).unwrap_or(false)
    }

    /// Materialize safe discards of still-live coefficients: a dynamic rule
    /// can discard a feature whose previous-λ coefficient is nonzero (the
    /// support shrinks along the path). Zero it, return its contribution to
    /// the residual, and invalidate the lazy correlations (the residual
    /// moved). Runs identically in the fused and unfused pipelines, after
    /// the strong set is classified.
    fn zero_discarded(&mut self, survive: &[bool]) -> Result<()> {
        let changed;
        if self.x.ncols() == 0 {
            // Store-only fit: serve the evicted column from a pinned
            // cursor (solver traffic, like the CD loop's own reads).
            let engine = self.engine;
            let store = engine.column_store().ok_or_else(|| {
                HssrError::Config("store-only fit lost its column store".into())
            })?;
            let mut pc = store.pin_cols();
            let (beta, r) = (&mut self.beta, &mut self.r);
            let mut err = None;
            changed = zero_discarded_units(survive, |j| {
                if beta[j] != 0.0 && err.is_none() {
                    match pc.col(j) {
                        Ok(col) => {
                            ops::axpy(beta[j], col, r);
                            beta[j] = 0.0;
                            true
                        }
                        Err(e) => {
                            err = Some(e);
                            false
                        }
                    }
                } else {
                    false
                }
            });
            if let Some(e) = err {
                return Err(e);
            }
        } else {
            let (x, beta, r) = (self.x, &mut self.beta, &mut self.r);
            changed = zero_discarded_units(survive, |j| {
                if beta[j] != 0.0 {
                    let b = beta[j];
                    ops::axpy(b, x.col(j), r);
                    beta[j] = 0.0;
                    true
                } else {
                    false
                }
            });
        }
        if changed {
            self.z_valid.iter_mut().for_each(|v| *v = false);
        }
        Ok(())
    }
}

/// [`BurstProblem`] view of [`GaussianLasso`] at one λ — the shared
/// [`dynamic_burst_solve`] drives CD bursts and gap-safe prunes through it.
struct GaussianBurst<'p, 'a> {
    prob: &'p mut GaussianLasso<'a>,
    lam: f64,
}

impl BurstProblem for GaussianBurst<'_, '_> {
    fn cycle(&mut self, work: &[usize], m: &mut LambdaMetrics) -> Result<f64> {
        m.coord_updates += work.len() as u64;
        let p = &mut *self.prob;
        let mut cols = ColSource::for_engine(p.engine, p.x);
        cd::cd_cycle_on(&mut cols, p.penalty, self.lam, work, &mut p.beta, &mut p.r)
    }

    fn rescreen_keep(&mut self, keep: &mut [bool], m: &mut LambdaMetrics) -> Result<()> {
        let p = &mut *self.prob;
        if let Some(rule) = p.safe_rule.as_mut() {
            let prev = PrevSolution { lambda: self.lam, r: &p.r, beta: Some(&p.beta) };
            let mut scanned = 0u64;
            rule.screen_routed(p.engine, p.x, &p.ctx, &prev, self.lam, keep, &mut scanned)?;
            m.cols_scanned += scanned;
        }
        Ok(())
    }

    fn evict(&mut self, j: usize) {
        let p = &mut *self.prob;
        if p.beta[j] == 0.0 || p.deferred.is_some() {
            return;
        }
        let b = p.beta[j];
        if p.x.ncols() == 0 {
            // Store-only fit: the design was never materialized, and this
            // trait method is infallible — park a read failure for
            // `solve` to surface after the burst driver returns.
            let engine = p.engine;
            let Some(store) = engine.column_store() else {
                p.deferred =
                    Some(HssrError::Config("store-only fit lost its column store".into()));
                return;
            };
            let mut pc = store.pin_cols();
            match pc.col(j) {
                Ok(col) => {
                    ops::axpy(b, col, &mut p.r);
                    p.beta[j] = 0.0;
                }
                Err(e) => p.deferred = Some(e),
            }
        } else {
            ops::axpy(b, p.x.col(j), &mut p.r);
            p.beta[j] = 0.0;
        }
    }
}

impl Problem for GaussianLasso<'_> {
    fn n_units(&self) -> usize {
        self.ctx.p
    }

    fn n_coef(&self) -> usize {
        self.ctx.p
    }

    fn lambda_max(&self) -> f64 {
        self.ctx.lambda_max
    }

    fn preamble_cols(&self) -> u64 {
        self.preamble
    }

    fn io_counters(&self) -> Option<&crate::data::store::StoreCounters> {
        self.engine.column_store().map(|s| s.counters())
    }

    fn has_safe_rule(&self) -> bool {
        self.safe_rule.is_some()
    }

    fn needs_kkt(&self) -> bool {
        // BasicPcd/SEDPP never KKT-check (exact / safe ⇒ nothing to verify).
        !matches!(self.rule, RuleKind::BasicPcd | RuleKind::Sedpp)
    }

    /// λ-ahead prefetch: predict λ_{k+1}'s working set with the SSR
    /// threshold at the *current* correlations (active features always
    /// included) and hand the columns to the engine's async prefetch
    /// service. Overlap only — a wrong prediction costs a wasted load,
    /// never correctness.
    fn prefetch_next(&mut self, lam: f64, lam_next: Option<f64>) {
        let Some(lam_next) = lam_next else { return };
        if self.engine.column_store().is_none() {
            return;
        }
        let t = ssr::threshold(self.penalty, lam_next, lam);
        let cols: Vec<usize> = (0..self.ctx.p)
            .filter(|&j| {
                self.beta[j] != 0.0 || (self.z_valid[j] && self.z[j].abs() >= t)
            })
            .collect();
        self.engine.prefetch_columns(&cols);
    }

    fn screen(
        &mut self,
        lam: f64,
        lam_prev: f64,
        run_safe: bool,
        fused: bool,
        survive: &mut [bool],
        m: &mut LambdaMetrics,
    ) -> Result<ScreenStage> {
        let p = self.ctx.p;
        let uses_ssr = self.rule.uses_ssr();
        let mut stage =
            ScreenStage { dynamic: self.dynamic_rule(), ..ScreenStage::default() };

        if fused && uses_ssr {
            // ---- fused screening (lines 2–10 in one traversal) ----
            let ssr_t = ssr::threshold(self.penalty, lam, lam_prev);
            let mut masked_d = 0usize;
            let mut rule_scanned = 0u64;
            let (fout, was_pointwise) = {
                let keep = if !run_safe {
                    None
                } else if let Some(rule) = self.safe_rule.as_mut() {
                    let prev =
                        PrevSolution { lambda: lam_prev, r: &self.r, beta: Some(&self.beta) };
                    rule.plan_routed(
                        self.engine,
                        self.x,
                        &self.ctx,
                        &prev,
                        lam,
                        survive,
                        &mut masked_d,
                        &mut rule_scanned,
                    )?
                } else {
                    None
                };
                let wp = keep.is_some();
                let out = self.engine.fused_screen(
                    self.x,
                    &self.r,
                    keep.as_deref(),
                    ssr_t,
                    survive,
                    &mut self.z,
                    &mut self.z_valid,
                )?;
                (out, wp)
            };
            m.cols_scanned += rule_scanned;
            stage.discarded = masked_d + fout.discarded;
            // Masked rules that discard report `dead` only alongside zero
            // discards, so the flag condition matches the unfused driver
            // exactly; pointwise rules flag purely on count.
            stage.rule_dead = !was_pointwise
                && self.safe_rule.as_ref().map(|ru| ru.dead()).unwrap_or(false);
            m.safe_size = fout.safe_size;
            m.cols_scanned += fout.cols_scanned;
            stage.strong = fout.strong;
            self.zero_discarded(survive)?;
            return Ok(stage);
        }

        // ---- unfused screening (Algorithm 1 lines 2–9) ----
        if run_safe {
            if let Some(rule) = self.safe_rule.as_mut() {
                let prev =
                    PrevSolution { lambda: lam_prev, r: &self.r, beta: Some(&self.beta) };
                let mut scanned = 0u64;
                stage.discarded = rule.screen_routed(
                    self.engine,
                    self.x,
                    &self.ctx,
                    &prev,
                    lam,
                    survive,
                    &mut scanned,
                )?;
                m.cols_scanned += scanned;
                stage.rule_dead = rule.dead();
            }
        }
        m.safe_size = survive.iter().filter(|&&s| s).count();

        // ---- line 4: refresh z over newly-entered safe features ----
        if uses_ssr {
            let stale: Vec<usize> =
                (0..p).filter(|&j| survive[j] && !self.z_valid[j]).collect();
            column_refresh(
                self.engine,
                self.x,
                &self.r,
                &stale,
                &mut self.z,
                &mut self.z_valid,
                &mut self.scratch,
                m,
            )?;
        }

        // ---- strong / optimizer set (line 10) ----
        stage.strong = match self.rule {
            RuleKind::BasicPcd => (0..p).collect(),
            RuleKind::ActiveCycling => {
                (0..p).filter(|&j| self.beta[j] != 0.0).collect()
            }
            RuleKind::Sedpp => (0..p).filter(|&j| survive[j]).collect(),
            _ => ssr::strong_set(self.penalty, lam, lam_prev, &self.z, survive),
        };
        self.zero_discarded(survive)?;
        Ok(stage)
    }

    fn solve(
        &mut self,
        lam: f64,
        lambda_index: usize,
        strong: &[usize],
        m: &mut LambdaMetrics,
    ) -> Result<()> {
        let dynamic = self.rescreen_every > 0 && self.dynamic_rule();
        if !dynamic {
            // The inner CD loop runs on the engine's column source: the
            // resident design natively, or a pinned store cursor when the
            // engine is out-of-core (a fully diskless fit).
            let mut cols = ColSource::for_engine(self.engine, self.x);
            let stats = cd::cd_solve_on(
                &mut cols,
                self.penalty,
                lam,
                strong,
                &mut self.beta,
                &mut self.r,
                self.tol,
                self.max_iter,
                lambda_index,
            )?;
            m.cd_cycles += stats.cycles;
            m.coord_updates += stats.coord_updates;
            if stats.cycles > 0 {
                self.z_valid.iter_mut().for_each(|v| *v = false);
            }
            return Ok(());
        }
        // Dynamic (gap-safe) solve: the shared burst driver runs CD in
        // bounded bursts, re-firing the rule between bursts at the
        // *current* residual so certified-inactive features leave the
        // working set mid-optimization (their coefficients zeroed back
        // into the residual first — safe, because the ball certificate is
        // against this λ's optimum).
        let (rescreen_every, max_iter, tol, n_units) =
            (self.rescreen_every, self.max_iter, self.tol, self.ctx.p);
        let ran = dynamic_burst_solve(
            &mut GaussianBurst { prob: self, lam },
            strong,
            n_units,
            rescreen_every,
            max_iter,
            tol,
            lambda_index,
            m,
        )?;
        if let Some(e) = self.deferred.take() {
            return Err(e);
        }
        if ran {
            self.z_valid.iter_mut().for_each(|v| *v = false);
        }
        Ok(())
    }

    fn rescreen(
        &mut self,
        lam: f64,
        survive: &mut [bool],
        in_strong: &[bool],
        m: &mut LambdaMetrics,
    ) -> Result<usize> {
        if !self.dynamic_rule() {
            return Ok(0);
        }
        let mut mask = survive.to_vec();
        if let Some(rule) = self.safe_rule.as_mut() {
            let prev = PrevSolution { lambda: lam, r: &self.r, beta: Some(&self.beta) };
            let mut scanned = 0u64;
            rule.screen_routed(
                self.engine,
                self.x,
                &self.ctx,
                &prev,
                lam,
                &mut mask,
                &mut scanned,
            )?;
            m.cols_scanned += scanned;
            // Fused epoch: the rule just scanned every column at the
            // current residual, and nothing touches the residual between
            // here and the KKT check (the mask below only clears survive
            // bits of zero-coefficient columns). Republishing the scan
            // into the lazy cache lets the KKT pass reuse these values
            // instead of re-traversing the candidate columns; the reuse
            // is bit-identical because a recompute would run the same
            // per-column reduction against the same residual. A rule
            // whose last screen took an inexact shortcut reports no scan
            // and the cache stays invalidated.
            if self.fused_epoch {
                if let Some(scan) = rule.last_scan() {
                    if scan.len() == self.z.len() {
                        self.z.copy_from_slice(scan);
                        self.z_valid.iter_mut().for_each(|v| *v = true);
                    }
                }
            }
        }
        let beta = &self.beta;
        Ok(apply_rescreen_mask(survive, &mask, in_strong, |j| beta[j] != 0.0))
    }

    fn kkt(
        &mut self,
        lam: f64,
        fused: bool,
        survive: &[bool],
        in_strong: &[bool],
        m: &mut LambdaMetrics,
    ) -> Result<Vec<usize>> {
        column_kkt(
            self.engine,
            self.x,
            &self.r,
            self.penalty,
            lam,
            fused,
            survive,
            in_strong,
            &mut self.z,
            &mut self.z_valid,
            &mut self.scratch,
            m,
        )
    }

    fn end_lambda(
        &mut self,
        _lam: f64,
        fused: bool,
        strong: &[usize],
        m: &mut LambdaMetrics,
    ) -> Result<()> {
        // Unfused driver: refresh z over the strong set so the next SSR
        // screening sees correlations at the final residual. (The fused
        // KKT pass already left them lazily refreshable instead.)
        let use_fused_kkt = fused && self.needs_kkt();
        if !use_fused_kkt && self.rule.uses_ssr() {
            column_refresh(
                self.engine,
                self.x,
                &self.r,
                strong,
                &mut self.z,
                &mut self.z_valid,
                &mut self.scratch,
                m,
            )?;
        }
        Ok(())
    }

    fn sparse_beta(&self) -> Vec<(usize, f64)> {
        (0..self.beta.len())
            .filter(|&j| self.beta[j] != 0.0)
            .map(|j| (j, self.beta[j]))
            .collect()
    }

    fn objective(&self, lam: f64) -> f64 {
        objective(&self.r, &self.beta, self.penalty, lam, self.ctx.n)
    }

    /// Checkpoint everything that feeds the next λ: β, the residual, the
    /// lazy correlations *with their validity mask* (serialized, not
    /// invalidated — a resumed fit must reproduce the uninterrupted fit's
    /// `cols_scanned` bit-for-bit), and the safe rule's phase state.
    fn save_state(&self) -> Option<Vec<u8>> {
        let mut w = ByteWriter::new();
        w.put_f64s(&self.beta);
        w.put_f64s(&self.r);
        w.put_f64s(&self.z);
        w.put_bools(&self.z_valid);
        let rule_state =
            self.safe_rule.as_ref().map(|ru| ru.save_state()).unwrap_or_default();
        w.put_blob(&rule_state);
        Some(w.into_bytes())
    }

    fn restore_state(&mut self, state: &[u8]) -> Result<()> {
        let mut rd = ByteReader::new(state);
        let beta = rd.get_f64s()?;
        let r = rd.get_f64s()?;
        let z = rd.get_f64s()?;
        let z_valid = rd.get_bools()?;
        let rule_state = rd.get_blob()?.to_vec();
        if beta.len() != self.ctx.p
            || r.len() != self.ctx.n
            || z.len() != self.ctx.p
            || z_valid.len() != self.ctx.p
        {
            return Err(HssrError::Corrupt(
                "lasso checkpoint state dimensions do not match the data".into(),
            ));
        }
        if let Some(rule) = self.safe_rule.as_mut() {
            rule.load_state(&rule_state)?;
        }
        self.beta = beta;
        self.r = r;
        self.z = z;
        self.z_valid = z_valid;
        Ok(())
    }
}

/// Fit the full path with the default scan engine: the native pool-backed
/// kernels, or — when `HSSR_ENGINE=ooc` — an out-of-core engine mounted on
/// a spilled store, so the whole suite can run with every scan served from
/// disk under an `HSSR_CACHE_MB` budget.
pub fn fit_lasso_path(ds: &Dataset, cfg: &PathConfig) -> Result<PathFit> {
    if let Some(engine) = ooc::env_engine_for(&ds.x, &ds.y)? {
        return fit_lasso_path_with_engine(ds, cfg, &engine);
    }
    fit_lasso_path_with_engine(ds, cfg, &NativeEngine::new())
}

/// Fit the full path with an explicit scan engine (native or PJRT).
pub fn fit_lasso_path_with_engine(
    ds: &Dataset,
    cfg: &PathConfig,
    engine: &dyn ScanEngine,
) -> Result<PathFit> {
    fit_lasso_path_warm_with_engine(ds, cfg, engine, None).map(|(fit, _)| fit)
}

/// [`fit_lasso_path_with_engine`] with the warm-start hooks: `warm` seeds
/// the walk when compatible (silently cold-starting otherwise), and the
/// completed fit's own [`WarmStart`] is returned for a registry.
pub fn fit_lasso_path_warm_with_engine(
    ds: &Dataset,
    cfg: &PathConfig,
    engine: &dyn ScanEngine,
    warm: Option<&WarmStart>,
) -> Result<(PathFit, Option<WarmStart>)> {
    let _scope = trace::FitScope::enter();
    let mut prob = traced_setup(engine, || GaussianLasso::new(ds, cfg, engine))?;
    let (fit, warm_out) = drive_warm(&mut prob, &cfg.driver(), warm)?;
    Ok((path_fit(fit), warm_out))
}

/// Trace the problem-construction window as a `setup` span (category
/// `fit`): the λmax/standardization scans run *here*, before any
/// [`crate::solver::driver::LambdaMetrics`] exist, so without this span a
/// store-backed fit's per-span I/O deltas could not sum to the store's
/// totals. Opened under the caller's [`trace::FitScope`] so the
/// summarizer groups it with the driver's spans. No-op when tracing is
/// off.
fn traced_setup<T>(engine: &dyn ScanEngine, build: impl FnOnce() -> Result<T>) -> Result<T> {
    if !trace::enabled() {
        return build();
    }
    let mut span = Span::begin("setup", "fit");
    span.arg_str("engine", engine.name());
    let io0 = engine.column_store().map(|s| s.counters().snapshot());
    let out = build();
    if let (Some(store), Some(io0)) = (engine.column_store(), io0) {
        let d = store.counters().snapshot().delta_since(&io0);
        span.arg_u64("cols_fetched", d.cols_fetched);
        span.arg_u64("chunk_loads", d.chunk_loads);
        span.arg_u64("bytes_read", d.bytes_read);
        span.arg_u64("cache_hits", d.cache_hits);
        span.arg_u64("stalls", d.stalls);
    }
    out
}

/// Fit the full path **entirely from a column store** — no resident
/// design. This is the serve/CV engine-routed entry: peak resident bytes
/// stay bounded by the store's chunk-cache budget (shared across
/// concurrent fits when callers clone the [`Arc`]), and `warm` seeds the
/// walk from a previously completed job's [`WarmStart`].
pub fn fit_lasso_path_store(
    store: Arc<ColumnStore>,
    cfg: &PathConfig,
    warm: Option<&WarmStart>,
) -> Result<(PathFit, Option<WarmStart>)> {
    let engine = ooc::OocEngine::from_shared(store);
    let dummy = DenseMatrix::zeros(engine.store().nrows(), 0);
    let _scope = trace::FitScope::enter();
    let mut prob = traced_setup(&engine, || GaussianLasso::from_store(&dummy, cfg, &engine))?;
    let (fit, warm_out) = drive_warm(&mut prob, &cfg.driver(), warm)?;
    Ok((path_fit(fit), warm_out))
}

fn path_fit(fit: DriverFit) -> PathFit {
    PathFit {
        lambdas: fit.lambdas,
        betas: fit.betas,
        metrics: fit.metrics,
        p: fit.p,
        lambda_max: fit.lambda_max,
        seconds: fit.seconds,
        rule: fit.rule,
        error: fit.error,
    }
}

/// Elastic-net objective `‖r‖²/(2n) + αλ‖β‖₁ + (1−α)λ/2·‖β‖²`.
pub fn objective(r: &[f64], beta: &[f64], penalty: Penalty, lam: f64, n: usize) -> f64 {
    let l1: f64 = beta.iter().map(|b| b.abs()).sum();
    let l2: f64 = beta.iter().map(|b| b * b).sum();
    ops::nrm2_sq(r) / (2.0 * n as f64)
        + penalty.alpha() * lam * l1
        + penalty.l2_weight() * lam * 0.5 * l2
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::data::DataSpec;

    fn small_cfg(rule: RuleKind) -> PathConfig {
        PathConfig { rule, n_lambda: 30, tol: 1e-9, ..PathConfig::default() }
    }

    fn max_beta_diff(a: &PathFit, b: &PathFit) -> f64 {
        let mut worst = 0.0f64;
        for k in 0..a.lambdas.len() {
            let da = a.beta_dense(k);
            let db = b.beta_dense(k);
            for j in 0..da.len() {
                worst = worst.max((da[j] - db[j]).abs());
            }
        }
        worst
    }

    /// Theorem 3.1: every strategy converges to the same solution path.
    #[test]
    fn all_rules_agree_on_solution() {
        let ds = DataSpec::synthetic(100, 60, 8).generate(42);
        let baseline = fit_lasso_path(&ds, &small_cfg(RuleKind::BasicPcd)).unwrap();
        for rule in [
            RuleKind::ActiveCycling,
            RuleKind::Ssr,
            RuleKind::Sedpp,
            RuleKind::SsrBedpp,
            RuleKind::SsrDome,
            RuleKind::SsrBedppSedpp,
            RuleKind::SsrGapSafe,
        ] {
            let fit = fit_lasso_path(&ds, &small_cfg(rule)).unwrap();
            let d = max_beta_diff(&baseline, &fit);
            assert!(d < 1e-5, "{:?} deviates from Basic PCD by {d}", rule);
        }
    }

    /// The fused single-pass driver and the unfused scan-then-filter driver
    /// must agree **bit-for-bit** — same solutions, same safe/strong set
    /// sizes at every λ — for every rule kind. (The randomized version of
    /// this check lives in `crate::prop`.)
    #[test]
    fn fused_driver_bit_identical_to_unfused() {
        let ds = DataSpec::gene_like(90, 250).generate(21);
        for rule in [
            RuleKind::BasicPcd,
            RuleKind::ActiveCycling,
            RuleKind::Ssr,
            RuleKind::Sedpp,
            RuleKind::SsrBedpp,
            RuleKind::SsrDome,
            RuleKind::SsrBedppSedpp,
            RuleKind::SsrGapSafe,
        ] {
            let fused = fit_lasso_path(
                &ds,
                &PathConfig { fused: true, ..small_cfg(rule) },
            )
            .unwrap();
            let unfused = fit_lasso_path(
                &ds,
                &PathConfig { fused: false, ..small_cfg(rule) },
            )
            .unwrap();
            assert_eq!(fused.betas, unfused.betas, "{rule:?} betas differ");
            for (k, (mf, mu)) in
                fused.metrics.iter().zip(unfused.metrics.iter()).enumerate()
            {
                assert_eq!(mf.safe_size, mu.safe_size, "{rule:?} |S| at λ#{k}");
                assert_eq!(mf.strong_size, mu.strong_size, "{rule:?} |H| at λ#{k}");
                assert_eq!(mf.violations, mu.violations, "{rule:?} viols at λ#{k}");
                assert_eq!(mf.nonzero, mu.nonzero, "{rule:?} nnz at λ#{k}");
            }
        }
    }

    #[test]
    fn first_lambda_gives_zero_solution() {
        let ds = DataSpec::synthetic(50, 30, 4).generate(1);
        let fit = fit_lasso_path(&ds, &small_cfg(RuleKind::SsrBedpp)).unwrap();
        assert_eq!(fit.nonzero_at(0), 0, "β(λmax) must be 0");
        assert!(fit.nonzero_at(fit.lambdas.len() - 1) > 0);
    }

    #[test]
    fn solution_satisfies_kkt_at_every_lambda() {
        let ds = DataSpec::gene_like(80, 50).generate(2);
        let fit = fit_lasso_path(&ds, &small_cfg(RuleKind::SsrBedpp)).unwrap();
        for (k, &lam) in fit.lambdas.iter().enumerate() {
            let b = fit.beta_dense(k);
            let r: Vec<f64> = {
                let f = ds.x.matvec(&b);
                ds.y.iter().zip(&f).map(|(y, v)| y - v).collect()
            };
            let z = crate::linalg::blocked::scan_all_vec(&ds.x, &r);
            for j in 0..ds.p() {
                if b[j] != 0.0 {
                    assert!(
                        (z[j] - lam * b[j].signum()).abs() < 1e-4,
                        "λ#{k} active {j}"
                    );
                } else {
                    assert!(z[j].abs() <= lam * (1.0 + 1e-3) + 1e-6, "λ#{k} inactive {j}");
                }
            }
        }
    }

    #[test]
    fn monotone_nonzero_growth_roughly() {
        let ds = DataSpec::synthetic(80, 40, 6).generate(3);
        let fit = fit_lasso_path(&ds, &small_cfg(RuleKind::Ssr)).unwrap();
        // support size at λmin must exceed support at λmax-side
        assert!(fit.nonzero_at(fit.lambdas.len() - 1) >= fit.nonzero_at(1));
    }

    #[test]
    fn hssr_scans_fewer_columns_than_ssr() {
        let ds = DataSpec::gene_like(100, 300).generate(4);
        let ssr = fit_lasso_path(&ds, &small_cfg(RuleKind::Ssr)).unwrap();
        let hssr = fit_lasso_path(&ds, &small_cfg(RuleKind::SsrBedpp)).unwrap();
        assert!(
            hssr.total_cols_scanned() < ssr.total_cols_scanned(),
            "hssr {} vs ssr {}",
            hssr.total_cols_scanned(),
            ssr.total_cols_scanned()
        );
        // and KKT work shrinks (the paper's central claim)
        assert!(hssr.total_kkt_checks() < ssr.total_kkt_checks());
    }

    #[test]
    fn safe_sizes_shrink_with_bedpp() {
        let ds = DataSpec::synthetic(80, 100, 5).generate(5);
        let fit = fit_lasso_path(&ds, &small_cfg(RuleKind::SsrBedpp)).unwrap();
        // near λmax the safe set must be well below p
        assert!(fit.metrics[1].safe_size < ds.p());
        // once the flag fires, safe_size = p
        let last = fit.metrics.last().unwrap();
        assert!(last.safe_size <= ds.p());
    }

    #[test]
    fn elastic_net_path_consistent_across_rules() {
        let ds = DataSpec::synthetic(70, 50, 6).generate(6);
        let mk = |rule| PathConfig {
            rule,
            penalty: Penalty::ElasticNet { alpha: 0.7 },
            n_lambda: 25,
            tol: 1e-9,
            ..PathConfig::default()
        };
        let base = fit_lasso_path(&ds, &mk(RuleKind::BasicPcd)).unwrap();
        for rule in
            [RuleKind::Ssr, RuleKind::SsrBedpp, RuleKind::Sedpp, RuleKind::SsrGapSafe]
        {
            let fit = fit_lasso_path(&ds, &mk(rule)).unwrap();
            assert!(max_beta_diff(&base, &fit) < 1e-5, "{rule:?} enet mismatch");
        }
    }

    /// The dynamic rule's extra machinery (mid-solve prunes + pre-KKT
    /// re-screens) must leave the KKT system satisfied and report its
    /// discards in the metrics.
    #[test]
    fn gapsafe_path_dynamic_rescreens_and_stays_exact() {
        let ds = DataSpec::gene_like(90, 250).generate(9);
        let fit = fit_lasso_path(&ds, &small_cfg(RuleKind::SsrGapSafe)).unwrap();
        let base = fit_lasso_path(&ds, &small_cfg(RuleKind::BasicPcd)).unwrap();
        assert!(max_beta_diff(&base, &fit) < 1e-5, "gap-safe path deviates");
        // Deep in the path the dynamic rule still screens (safe_size < p),
        // where the static BEDPP rule has long been flag-shut.
        let last = fit.metrics.last().unwrap();
        assert!(last.safe_size < ds.p(), "gap-safe dead at λmin: |S| = {}", last.safe_size);
        let rescreens: usize = fit.metrics.iter().map(|m| m.rescreen_discards).sum();
        assert!(rescreens > 0, "dynamic re-screens never fired");
        // And the mid-solve prune knob can be turned off without changing
        // the solution.
        let off = fit_lasso_path(
            &ds,
            &PathConfig { rescreen_every: 0, ..small_cfg(RuleKind::SsrGapSafe) },
        )
        .unwrap();
        assert!(max_beta_diff(&fit, &off) < 1e-5, "rescreen_every=0 deviates");
    }

    #[test]
    fn explicit_lambda_grid_respected() {
        let ds = DataSpec::synthetic(40, 20, 3).generate(7);
        let cfg = PathConfig {
            lambdas: Some(vec![0.5, 0.3, 0.1]),
            ..small_cfg(RuleKind::Ssr)
        };
        let fit = fit_lasso_path(&ds, &cfg).unwrap();
        assert_eq!(fit.lambdas, vec![0.5, 0.3, 0.1]);
        assert_eq!(fit.betas.len(), 3);
    }

    /// Crash-resume: a fit killed after k λs and resumed from its
    /// checkpoint must be bit-identical — βs, metrics, scan accounting —
    /// to one that never stopped. Exercised for the headline hybrid rule
    /// (static BEDPP phase state) and the frozen re-hybridized rule.
    #[test]
    fn checkpoint_resume_is_bit_identical() {
        let dir = std::env::temp_dir().join("hssr_path_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let ds = DataSpec::gene_like(70, 120).generate(31);
        for rule in [RuleKind::SsrBedpp, RuleKind::SsrBedppSedpp, RuleKind::SsrGapSafe] {
            let full = fit_lasso_path(&ds, &small_cfg(rule)).unwrap();
            let grid = full.lambdas.clone();
            let ck = dir.join(format!("{rule:?}.ckpt"));
            let _ = std::fs::remove_file(&ck);
            // "Crash" after 11 of 30 λs: fit only the prefix, checkpointing.
            let prefix_cfg = PathConfig {
                lambdas: Some(grid[..11].to_vec()),
                checkpoint: Some(ck.clone()),
                ..small_cfg(rule)
            };
            fit_lasso_path(&ds, &prefix_cfg).unwrap();
            // Resume over the full grid from the same checkpoint.
            let resume_cfg = PathConfig {
                lambdas: Some(grid.clone()),
                checkpoint: Some(ck.clone()),
                ..small_cfg(rule)
            };
            let resumed = fit_lasso_path(&ds, &resume_cfg).unwrap();
            assert_eq!(resumed.lambdas, full.lambdas, "{rule:?} grid");
            assert_eq!(resumed.betas, full.betas, "{rule:?} betas differ");
            for (k, (ma, mb)) in
                full.metrics.iter().zip(resumed.metrics.iter()).enumerate()
            {
                assert_eq!(ma, mb, "{rule:?} metrics at λ#{k}");
            }
            // A checkpoint from a different rule is refused, typed.
            let other = PathConfig {
                lambdas: Some(grid.clone()),
                checkpoint: Some(ck.clone()),
                ..small_cfg(RuleKind::Ssr)
            };
            assert!(matches!(
                fit_lasso_path(&ds, &other),
                Err(crate::error::HssrError::Config(_))
            ));
            let _ = std::fs::remove_file(&ck);
        }
    }

    /// A fit that never materializes the design — safe-rule precompute,
    /// screening, KKT, and the inner CD loop all served from the store —
    /// must be bit-identical to the dense in-memory fit, and its own
    /// `WarmStart` must seed an extended-grid fit past the shared prefix.
    #[test]
    fn store_backed_fit_matches_dense_bitwise() {
        let ds = DataSpec::gene_like(60, 140).generate(17);
        for rule in [RuleKind::Ssr, RuleKind::SsrBedpp, RuleKind::SsrGapSafe] {
            let cfg = small_cfg(rule);
            let dense = fit_lasso_path_with_engine(&ds, &cfg, &NativeEngine::new()).unwrap();
            let engine = ooc::OocEngine::spill(&ds.x, &ds.y, 1 << 18).unwrap();
            let (fit, warm) =
                fit_lasso_path_store(engine.shared_store(), &cfg, None).unwrap();
            assert_eq!(fit.lambdas, dense.lambdas, "{rule:?} grid");
            assert_eq!(fit.betas, dense.betas, "{rule:?} betas differ");
            let warm = warm.expect("store fit must emit a warm start");
            assert_eq!(warm.prefix_len(), fit.lambdas.len());
            // Warm-started refit over a longer grid: prefix adopted
            // verbatim, tail identical to a cold fit of the same grid.
            let mut grid = fit.lambdas.clone();
            let last = *grid.last().unwrap();
            grid.push(last * 0.8);
            let wcfg = PathConfig { lambdas: Some(grid.clone()), ..cfg.clone() };
            let (wfit, _) =
                fit_lasso_path_store(engine.shared_store(), &wcfg, Some(&warm)).unwrap();
            let (cold, _) =
                fit_lasso_path_store(engine.shared_store(), &wcfg, None).unwrap();
            assert_eq!(wfit.betas, cold.betas, "{rule:?} warm tail deviates");
        }
    }

    #[test]
    fn objective_decreases_along_path_fit() {
        let ds = DataSpec::synthetic(60, 30, 4).generate(8);
        let fit = fit_lasso_path(&ds, &small_cfg(RuleKind::SsrBedpp)).unwrap();
        // residual-only part of the loss shrinks as λ decreases
        let first = fit.metrics[1].objective;
        let last = fit.metrics.last().unwrap().objective;
        assert!(last < first);
    }
}
