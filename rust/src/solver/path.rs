//! Pathwise coordinate descent with hybrid safe-strong screening —
//! **Algorithm 1** of the paper, generalized over all the "Method" rows of
//! its tables:
//!
//! | [`RuleKind`]        | safe set `S`         | optimizer set `H`       | KKT check over |
//! |---------------------|----------------------|-------------------------|----------------|
//! | `BasicPcd`          | all                  | all                     | — (exact)      |
//! | `ActiveCycling`     | all                  | ever-active set         | all \ H        |
//! | `Ssr`               | all                  | SSR strong set          | all \ H        |
//! | `Sedpp`             | SEDPP set            | `S` (safe ⇒ no check)   | —              |
//! | `SsrBedpp`          | BEDPP set            | SSR ∩ S                 | `S \ H`        |
//! | `SsrDome`           | Dome set             | SSR ∩ S                 | `S \ H`        |
//! | `SsrBedppSedpp`     | BEDPP→frozen-SEDPP   | SSR ∩ S                 | `S \ H`        |
//!
//! The `z_j = x_jᵀr/n` values are maintained lazily exactly as Algorithm 1
//! prescribes: screening at `λ_k` reuses the values computed during KKT
//! checking at `λ_{k−1}`; only features newly entering the safe set are
//! refreshed (line 4). The safe rule is switched off permanently once it
//! stops discarding (`Flag`, lines 6–8).
//!
//! ## Fused execution (default)
//!
//! With [`PathConfig::fused`] (the default), each λ step issues **one**
//! engine pass where the unfused driver issued three traversals:
//!
//! * screening runs through [`ScanEngine::fused_screen`] — the safe rule
//!   contributes a per-column predicate via
//!   [`crate::screening::SafeRule::plan`] (BEDPP/Dome; sequential rules
//!   screen into the mask first), and the kernel applies the predicate,
//!   refreshes stale `z_j`, and classifies against the SSR threshold per
//!   column;
//! * the post-convergence check runs through [`ScanEngine::fused_kkt`] —
//!   one traversal recomputes `z_j` over `S \ H` and tests KKT. The
//!   unfused driver's separate end-of-step strong-set refresh disappears
//!   entirely: the residual is unchanged until the next λ's screening, so
//!   the fused screen lazily refreshes the strong columns there with
//!   bit-identical values (and the final λ's refresh is never paid).
//!
//! Selections and solutions are bit-identical to the unfused driver
//! (`fused: false`, kept for A/B benchmarking and the equivalence property
//! test in [`crate::prop`]).

use std::time::Instant;

use crate::data::Dataset;
use crate::error::Result;
use crate::linalg::ops;
use crate::runtime::{native::NativeEngine, ScanEngine};
use crate::screening::{make_safe_rule, ssr, PrevSolution, RuleKind, SafeContext};
use crate::solver::{cd, kkt, lambda::GridKind, Penalty};

/// Configuration for a pathwise fit.
#[derive(Clone, Debug)]
pub struct PathConfig {
    /// Screening strategy (paper "Method").
    pub rule: RuleKind,
    /// Penalty family.
    pub penalty: Penalty,
    /// Number of λ grid points (paper: 100).
    pub n_lambda: usize,
    /// Smallest λ as a fraction of λmax (paper: 0.1).
    pub lambda_min_ratio: f64,
    /// Grid spacing (paper: linear on λ/λmax).
    pub grid: GridKind,
    /// Convergence tolerance on max |Δβ| per cycle.
    pub tol: f64,
    /// Maximum CD cycles per λ (per violation round).
    pub max_iter: usize,
    /// Explicit λ grid (overrides `n_lambda`/`lambda_min_ratio`).
    pub lambdas: Option<Vec<f64>>,
    /// Drive the fused single-pass screening/KKT pipeline (default). The
    /// unfused scan-then-filter driver is retained for benchmarking and
    /// equivalence testing; both select identical feature sets.
    pub fused: bool,
}

impl Default for PathConfig {
    fn default() -> Self {
        PathConfig {
            rule: RuleKind::SsrBedpp,
            penalty: Penalty::Lasso,
            n_lambda: 100,
            lambda_min_ratio: 0.1,
            grid: GridKind::Linear,
            tol: 1e-7,
            max_iter: 100_000,
            lambdas: None,
            fused: true,
        }
    }
}

/// Per-λ instrumentation (feeds Figures 1/3 and the ablation benches).
#[derive(Clone, Copy, Debug, Default)]
pub struct LambdaMetrics {
    /// λ value.
    pub lambda: f64,
    /// |S| — features surviving safe screening (= p when no safe rule).
    pub safe_size: usize,
    /// |H| — features handed to the optimizer (after violation rounds).
    pub strong_size: usize,
    /// Features KKT-checked after convergence.
    pub kkt_checked: usize,
    /// KKT violations detected (features re-added).
    pub violations: usize,
    /// CD cycles spent.
    pub cd_cycles: usize,
    /// Individual coordinate updates.
    pub coord_updates: u64,
    /// Columns read by screening/KKT scans at this λ.
    pub cols_scanned: u64,
    /// Nonzero coefficients at the solution.
    pub nonzero: usize,
    /// Objective value at the solution.
    pub objective: f64,
}

/// Result of a pathwise fit.
#[derive(Clone, Debug)]
pub struct PathFit {
    /// The λ grid actually used (decreasing).
    pub lambdas: Vec<f64>,
    /// Sparse coefficient vectors, one per λ: `(feature, value)` pairs.
    pub betas: Vec<Vec<(usize, f64)>>,
    /// Per-λ instrumentation.
    pub metrics: Vec<LambdaMetrics>,
    /// Number of features.
    pub p: usize,
    /// λmax computed from the data.
    pub lambda_max: f64,
    /// Wall-clock seconds for the whole path.
    pub seconds: f64,
    /// Strategy used.
    pub rule: RuleKind,
}

impl PathFit {
    /// Number of nonzero coefficients at grid index `k`.
    pub fn nonzero_at(&self, k: usize) -> usize {
        self.betas[k].len()
    }

    /// Densify the coefficient vector at grid index `k`.
    pub fn beta_dense(&self, k: usize) -> Vec<f64> {
        let mut b = vec![0.0; self.p];
        for &(j, v) in &self.betas[k] {
            b[j] = v;
        }
        b
    }

    /// Total columns scanned over the whole path (memory-traffic proxy,
    /// §3.2.3).
    pub fn total_cols_scanned(&self) -> u64 {
        self.metrics.iter().map(|m| m.cols_scanned).sum()
    }

    /// Total KKT checks performed over the path.
    pub fn total_kkt_checks(&self) -> u64 {
        self.metrics.iter().map(|m| m.kkt_checked as u64).sum()
    }

    /// Total violations over the path.
    pub fn total_violations(&self) -> u64 {
        self.metrics.iter().map(|m| m.violations as u64).sum()
    }
}

/// Fit the full path with the default (native, pool-backed) scan engine.
pub fn fit_lasso_path(ds: &Dataset, cfg: &PathConfig) -> Result<PathFit> {
    fit_lasso_path_with_engine(ds, cfg, &NativeEngine::new())
}

/// Fit the full path with an explicit scan engine (native or PJRT).
pub fn fit_lasso_path_with_engine(
    ds: &Dataset,
    cfg: &PathConfig,
    engine: &dyn ScanEngine,
) -> Result<PathFit> {
    cfg.penalty.validate()?;
    let start = Instant::now();
    let x = &ds.x;
    let n = ds.n();
    let p = ds.p();
    let penalty = cfg.penalty;
    let ctx = SafeContext::build(x, &ds.y, penalty, cfg.rule.needs_star());
    let lambdas = match &cfg.lambdas {
        Some(ls) => ls.clone(),
        None => crate::solver::lambda::grid(
            ctx.lambda_max,
            cfg.lambda_min_ratio,
            cfg.n_lambda,
            cfg.grid,
        ),
    };
    // --- mutable path state ---
    let mut beta = vec![0.0f64; p];
    let mut r = ds.y.clone();
    // z_j = x_jᵀr/n at the most recent residual where it was computed.
    let mut z: Vec<f64> = ctx.xty.iter().map(|v| v / n as f64).collect();
    let mut z_valid = vec![true; p];
    let mut safe_rule = make_safe_rule(cfg.rule);
    let mut flag_off = safe_rule.is_none(); // Algorithm 1 `Flag`
    let uses_ssr = cfg.rule.uses_ssr();
    let use_fused_screen = cfg.fused && uses_ssr;
    // BasicPcd/SEDPP never KKT-check (exact / safe ⇒ nothing to verify).
    let use_fused_kkt =
        cfg.fused && !matches!(cfg.rule, RuleKind::BasicPcd | RuleKind::Sedpp);
    let mut betas = Vec::with_capacity(lambdas.len());
    let mut metrics = Vec::with_capacity(lambdas.len());
    let mut scratch = vec![0.0f64; p];

    let mut lam_prev = ctx.lambda_max;
    for (k, &lam) in lambdas.iter().enumerate() {
        let mut m = LambdaMetrics { lambda: lam, ..Default::default() };
        let mut survive = vec![true; p];
        let mut strong: Vec<usize>;

        if use_fused_screen {
            // ---- fused screening (lines 2–10 in one traversal) ----
            let ssr_t = ssr::threshold(penalty, lam, lam_prev);
            let mut masked_d = 0usize;
            let mut planned = false;
            let (fout, was_pointwise) = {
                let keep = if flag_off {
                    None
                } else if let Some(rule) = safe_rule.as_mut() {
                    planned = true;
                    let prev = PrevSolution { lambda: lam_prev, r: &r };
                    rule.plan(x, &ctx, &prev, lam, &mut survive, &mut masked_d)
                } else {
                    None
                };
                let wp = keep.is_some();
                let out = engine.fused_screen(
                    x,
                    &r,
                    keep.as_deref(),
                    ssr_t,
                    &mut survive,
                    &mut z,
                    &mut z_valid,
                )?;
                (out, wp)
            };
            if planned {
                let discarded = masked_d + fout.discarded;
                // Masked rules that discard report `dead` only alongside
                // zero discards, so the flag condition matches the unfused
                // driver exactly; pointwise rules flag purely on count.
                let rule_dead = !was_pointwise
                    && safe_rule.as_ref().map(|ru| ru.dead()).unwrap_or(false);
                if discarded == 0 || rule_dead {
                    flag_off = true; // |S| = p ⇒ Flag ← TRUE
                    survive.iter_mut().for_each(|s| *s = true);
                }
            }
            m.safe_size = fout.safe_size;
            m.cols_scanned += fout.cols_scanned;
            strong = fout.strong;
        } else {
            // ---- unfused screening (Algorithm 1 lines 2–9) ----
            if !flag_off {
                if let Some(rule) = safe_rule.as_mut() {
                    let prev = PrevSolution { lambda: lam_prev, r: &r };
                    let discarded = rule.screen(x, &ctx, &prev, lam, &mut survive);
                    if discarded == 0 || rule.dead() {
                        flag_off = true; // |S| = p ⇒ Flag ← TRUE
                        survive.iter_mut().for_each(|s| *s = true);
                    }
                }
            }
            m.safe_size = survive.iter().filter(|&&s| s).count();

            // ---- line 4: refresh z over newly-entered safe features ----
            if uses_ssr {
                let stale: Vec<usize> =
                    (0..p).filter(|&j| survive[j] && !z_valid[j]).collect();
                if !stale.is_empty() {
                    engine.scan_subset(x, &r, &stale, &mut scratch[..stale.len()])?;
                    for (s, &j) in stale.iter().enumerate() {
                        z[j] = scratch[s];
                        z_valid[j] = true;
                    }
                    m.cols_scanned += stale.len() as u64;
                }
            }

            // ---- strong / optimizer set (line 10) ----
            strong = match cfg.rule {
                RuleKind::BasicPcd => (0..p).collect(),
                RuleKind::ActiveCycling => {
                    (0..p).filter(|&j| beta[j] != 0.0).collect()
                }
                RuleKind::Sedpp => (0..p).filter(|&j| survive[j]).collect(),
                _ => ssr::strong_set(penalty, lam, lam_prev, &z, &survive),
            };
        }

        let mut in_strong = vec![false; p];
        for &j in &strong {
            in_strong[j] = true;
        }

        // ---- solve + KKT loop (lines 11–18) ----
        loop {
            let stats =
                cd::cd_solve(x, penalty, lam, &strong, &mut beta, &mut r, cfg.tol, cfg.max_iter, k)?;
            m.cd_cycles += stats.cycles;
            m.coord_updates += stats.coord_updates;
            if stats.cycles > 0 {
                z_valid.iter_mut().for_each(|v| *v = false);
            }
            if matches!(cfg.rule, RuleKind::BasicPcd | RuleKind::Sedpp) {
                break; // exact / safe ⇒ no KKT checking
            }
            if use_fused_kkt {
                // One traversal: candidate z + KKT test. The strong columns
                // are deliberately NOT refreshed here (refresh_strong =
                // false): the residual does not change between this final
                // round and the next λ's screening, so the fused screen
                // picks them up as stale there with bit-identical values —
                // no redundant rescans on violation rounds, and the last
                // λ's strong refresh is skipped entirely.
                let fout = engine.fused_kkt(
                    x,
                    &r,
                    &survive,
                    &in_strong,
                    &|zj: f64| kkt::violates(penalty, lam, zj),
                    false,
                    &mut z,
                    &mut z_valid,
                )?;
                m.cols_scanned += fout.cols_scanned;
                m.kkt_checked += fout.checked;
                if fout.violations.is_empty() {
                    break;
                }
                m.violations += fout.violations.len();
                for &j in &fout.violations {
                    in_strong[j] = true;
                }
                strong.extend(fout.violations);
            } else {
                // KKT check set (line 14–15), unfused.
                let check: Vec<usize> = match cfg.rule {
                    RuleKind::ActiveCycling | RuleKind::Ssr => {
                        (0..p).filter(|&j| !in_strong[j]).collect()
                    }
                    _ => (0..p).filter(|&j| survive[j] && !in_strong[j]).collect(),
                };
                if check.is_empty() {
                    break;
                }
                engine.scan_subset(x, &r, &check, &mut scratch[..check.len()])?;
                for (s, &j) in check.iter().enumerate() {
                    z[j] = scratch[s];
                    z_valid[j] = true;
                }
                m.cols_scanned += check.len() as u64;
                m.kkt_checked += check.len();
                let viols = kkt::violations(penalty, lam, &check, &scratch[..check.len()]);
                if viols.is_empty() {
                    break;
                }
                m.violations += viols.len();
                for &j in &viols {
                    in_strong[j] = true;
                }
                strong.extend(viols);
            }
        }

        // Unfused driver: refresh z over the strong set so the next SSR
        // screening sees correlations at the final residual. (The fused
        // KKT pass already did this in its final round.)
        if !use_fused_kkt && uses_ssr && !strong.is_empty() {
            engine.scan_subset(x, &r, &strong, &mut scratch[..strong.len()])?;
            for (s, &j) in strong.iter().enumerate() {
                z[j] = scratch[s];
                z_valid[j] = true;
            }
            m.cols_scanned += strong.len() as u64;
        }

        m.strong_size = strong.len();
        let sparse: Vec<(usize, f64)> =
            (0..p).filter(|&j| beta[j] != 0.0).map(|j| (j, beta[j])).collect();
        m.nonzero = sparse.len();
        m.objective = objective(&r, &beta, penalty, lam, n);
        betas.push(sparse);
        metrics.push(m);
        lam_prev = lam;
    }
    Ok(PathFit {
        lambdas,
        betas,
        metrics,
        p,
        lambda_max: ctx.lambda_max,
        seconds: start.elapsed().as_secs_f64(),
        rule: cfg.rule,
    })
}

/// Elastic-net objective `‖r‖²/(2n) + αλ‖β‖₁ + (1−α)λ/2·‖β‖²`.
pub fn objective(r: &[f64], beta: &[f64], penalty: Penalty, lam: f64, n: usize) -> f64 {
    let l1: f64 = beta.iter().map(|b| b.abs()).sum();
    let l2: f64 = beta.iter().map(|b| b * b).sum();
    ops::nrm2_sq(r) / (2.0 * n as f64)
        + penalty.alpha() * lam * l1
        + penalty.l2_weight() * lam * 0.5 * l2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataSpec;

    fn small_cfg(rule: RuleKind) -> PathConfig {
        PathConfig { rule, n_lambda: 30, tol: 1e-9, ..PathConfig::default() }
    }

    fn max_beta_diff(a: &PathFit, b: &PathFit) -> f64 {
        let mut worst = 0.0f64;
        for k in 0..a.lambdas.len() {
            let da = a.beta_dense(k);
            let db = b.beta_dense(k);
            for j in 0..da.len() {
                worst = worst.max((da[j] - db[j]).abs());
            }
        }
        worst
    }

    /// Theorem 3.1: every strategy converges to the same solution path.
    #[test]
    fn all_rules_agree_on_solution() {
        let ds = DataSpec::synthetic(100, 60, 8).generate(42);
        let baseline = fit_lasso_path(&ds, &small_cfg(RuleKind::BasicPcd)).unwrap();
        for rule in [
            RuleKind::ActiveCycling,
            RuleKind::Ssr,
            RuleKind::Sedpp,
            RuleKind::SsrBedpp,
            RuleKind::SsrDome,
            RuleKind::SsrBedppSedpp,
        ] {
            let fit = fit_lasso_path(&ds, &small_cfg(rule)).unwrap();
            let d = max_beta_diff(&baseline, &fit);
            assert!(d < 1e-5, "{:?} deviates from Basic PCD by {d}", rule);
        }
    }

    /// The fused single-pass driver and the unfused scan-then-filter driver
    /// must agree **bit-for-bit** — same solutions, same safe/strong set
    /// sizes at every λ — for every rule kind. (The randomized version of
    /// this check lives in `crate::prop`.)
    #[test]
    fn fused_driver_bit_identical_to_unfused() {
        let ds = DataSpec::gene_like(90, 250).generate(21);
        for rule in [
            RuleKind::BasicPcd,
            RuleKind::ActiveCycling,
            RuleKind::Ssr,
            RuleKind::Sedpp,
            RuleKind::SsrBedpp,
            RuleKind::SsrDome,
            RuleKind::SsrBedppSedpp,
        ] {
            let fused = fit_lasso_path(&ds, &small_cfg(rule)).unwrap();
            let unfused = fit_lasso_path(
                &ds,
                &PathConfig { fused: false, ..small_cfg(rule) },
            )
            .unwrap();
            assert_eq!(fused.betas, unfused.betas, "{rule:?} betas differ");
            for (k, (mf, mu)) in
                fused.metrics.iter().zip(unfused.metrics.iter()).enumerate()
            {
                assert_eq!(mf.safe_size, mu.safe_size, "{rule:?} |S| at λ#{k}");
                assert_eq!(mf.strong_size, mu.strong_size, "{rule:?} |H| at λ#{k}");
                assert_eq!(mf.violations, mu.violations, "{rule:?} viols at λ#{k}");
                assert_eq!(mf.nonzero, mu.nonzero, "{rule:?} nnz at λ#{k}");
            }
        }
    }

    #[test]
    fn first_lambda_gives_zero_solution() {
        let ds = DataSpec::synthetic(50, 30, 4).generate(1);
        let fit = fit_lasso_path(&ds, &small_cfg(RuleKind::SsrBedpp)).unwrap();
        assert_eq!(fit.nonzero_at(0), 0, "β(λmax) must be 0");
        assert!(fit.nonzero_at(fit.lambdas.len() - 1) > 0);
    }

    #[test]
    fn solution_satisfies_kkt_at_every_lambda() {
        let ds = DataSpec::gene_like(80, 50).generate(2);
        let fit = fit_lasso_path(&ds, &small_cfg(RuleKind::SsrBedpp)).unwrap();
        for (k, &lam) in fit.lambdas.iter().enumerate() {
            let b = fit.beta_dense(k);
            let r: Vec<f64> = {
                let f = ds.x.matvec(&b);
                ds.y.iter().zip(&f).map(|(y, v)| y - v).collect()
            };
            let z = crate::linalg::blocked::scan_all_vec(&ds.x, &r);
            for j in 0..ds.p() {
                if b[j] != 0.0 {
                    assert!(
                        (z[j] - lam * b[j].signum()).abs() < 1e-4,
                        "λ#{k} active {j}"
                    );
                } else {
                    assert!(z[j].abs() <= lam * (1.0 + 1e-3) + 1e-6, "λ#{k} inactive {j}");
                }
            }
        }
    }

    #[test]
    fn monotone_nonzero_growth_roughly() {
        let ds = DataSpec::synthetic(80, 40, 6).generate(3);
        let fit = fit_lasso_path(&ds, &small_cfg(RuleKind::Ssr)).unwrap();
        // support size at λmin must exceed support at λmax-side
        assert!(fit.nonzero_at(fit.lambdas.len() - 1) >= fit.nonzero_at(1));
    }

    #[test]
    fn hssr_scans_fewer_columns_than_ssr() {
        let ds = DataSpec::gene_like(100, 300).generate(4);
        let ssr = fit_lasso_path(&ds, &small_cfg(RuleKind::Ssr)).unwrap();
        let hssr = fit_lasso_path(&ds, &small_cfg(RuleKind::SsrBedpp)).unwrap();
        assert!(
            hssr.total_cols_scanned() < ssr.total_cols_scanned(),
            "hssr {} vs ssr {}",
            hssr.total_cols_scanned(),
            ssr.total_cols_scanned()
        );
        // and KKT work shrinks (the paper's central claim)
        assert!(hssr.total_kkt_checks() < ssr.total_kkt_checks());
    }

    #[test]
    fn safe_sizes_shrink_with_bedpp() {
        let ds = DataSpec::synthetic(80, 100, 5).generate(5);
        let fit = fit_lasso_path(&ds, &small_cfg(RuleKind::SsrBedpp)).unwrap();
        // near λmax the safe set must be well below p
        assert!(fit.metrics[1].safe_size < ds.p());
        // once the flag fires, safe_size = p
        let last = fit.metrics.last().unwrap();
        assert!(last.safe_size <= ds.p());
    }

    #[test]
    fn elastic_net_path_consistent_across_rules() {
        let ds = DataSpec::synthetic(70, 50, 6).generate(6);
        let mk = |rule| PathConfig {
            rule,
            penalty: Penalty::ElasticNet { alpha: 0.7 },
            n_lambda: 25,
            tol: 1e-9,
            ..PathConfig::default()
        };
        let base = fit_lasso_path(&ds, &mk(RuleKind::BasicPcd)).unwrap();
        for rule in [RuleKind::Ssr, RuleKind::SsrBedpp, RuleKind::Sedpp] {
            let fit = fit_lasso_path(&ds, &mk(rule)).unwrap();
            assert!(max_beta_diff(&base, &fit) < 1e-5, "{rule:?} enet mismatch");
        }
    }

    #[test]
    fn explicit_lambda_grid_respected() {
        let ds = DataSpec::synthetic(40, 20, 3).generate(7);
        let cfg = PathConfig {
            lambdas: Some(vec![0.5, 0.3, 0.1]),
            ..small_cfg(RuleKind::Ssr)
        };
        let fit = fit_lasso_path(&ds, &cfg).unwrap();
        assert_eq!(fit.lambdas, vec![0.5, 0.3, 0.1]);
        assert_eq!(fit.betas.len(), 3);
    }

    #[test]
    fn objective_decreases_along_path_fit() {
        let ds = DataSpec::synthetic(60, 30, 4).generate(8);
        let fit = fit_lasso_path(&ds, &small_cfg(RuleKind::SsrBedpp)).unwrap();
        // residual-only part of the loss shrinks as λ decreases
        let first = fit.metrics[1].objective;
        let last = fit.metrics.last().unwrap().objective;
        assert!(last < first);
    }
}
