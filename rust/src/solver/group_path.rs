//! Pathwise group descent with screening — Algorithm 1 adapted to the group
//! lasso (paper §4.2 and §5.2) and the group elastic net (§5 at group
//! granularity). Methods: Basic GD, AC, SSR, SEDPP, and SSR-BEDPP
//! (Table 3).
//!
//! The λ-loop lives in the **generic driver**
//! ([`crate::solver::driver::drive`]); this module contributes the
//! group-unit problem [`GroupLassoProblem`] — blockwise group descent,
//! lazy `‖X_gᵀr‖/n` norms, the group safe rules, and the `αλ√W_g` KKT
//! threshold (the α scaling threads the elastic-net [`Penalty`] through
//! every stage, exactly as [`crate::solver::path::GaussianLasso`] does for
//! columns) — plus the thin [`fit_group_path`] shims.
//!
//! Like the lasso driver, the default execution is **fused**: screening
//! runs through [`ScanEngine::fused_group_screen`] (the group BEDPP rule
//! contributes a per-group predicate via `SafeRule::plan`, and one
//! pool-parallel pass refreshes stale norms and classifies against the
//! group-SSR threshold — a true single-traversal kernel on
//! [`NativeEngine`]), and the post-convergence check runs through
//! [`ScanEngine::fused_group_kkt`] — one traversal recomputing `‖X_gᵀr‖/n`
//! per surviving group, testing KKT for non-strong groups, with the
//! end-of-step strong refresh handled lazily at the next λ. `fused: false`
//! retains the separate-traversal driver; both select identical group
//! sets.

use crate::data::{GroupLayout, GroupedDataset};
use crate::error::{HssrError, Result};
use crate::linalg::{ops, DenseMatrix};
use crate::runtime::{native::NativeEngine, ooc, Precision, ScanEngine};
use crate::screening::group::{make_group_safe_rule, GroupSafeContext};
use crate::screening::{PrevSolution, RuleKind, SafeRule};
use crate::serialize::{ByteReader, ByteWriter};
use crate::solver::columns::ColSource;
use crate::solver::driver::{
    apply_rescreen_mask, drive, dynamic_burst_solve, fused_default, zero_discarded_units,
    BurstProblem, DriverConfig, PathError, Problem, ScreenStage,
};
use crate::solver::lambda::GridKind;
use crate::solver::path::LambdaMetrics;
use crate::solver::{gd, kkt, Penalty};

/// Configuration for a group-lasso / group elastic-net path fit.
#[derive(Clone, Debug)]
pub struct GroupPathConfig {
    /// Strategy — one of `BasicPcd` (reported as "Basic GD"), `ActiveCycling`,
    /// `Ssr`, `Sedpp`, `SsrBedpp`.
    pub rule: RuleKind,
    /// Penalty family (`Lasso`, or `ElasticNet { alpha }` for the group
    /// elastic net `αλΣ√W_g‖β_g‖ + (1−α)λ/2·‖β‖²`).
    pub penalty: Penalty,
    /// Number of λ grid points.
    pub n_lambda: usize,
    /// Smallest λ as a fraction of λmax.
    pub lambda_min_ratio: f64,
    /// Grid spacing.
    pub grid: GridKind,
    /// Convergence tolerance.
    pub tol: f64,
    /// Max group-descent cycles per λ per round.
    pub max_iter: usize,
    /// Explicit grid override.
    pub lambdas: Option<Vec<f64>>,
    /// Drive the fused group-norm/KKT pipeline (default; see module docs).
    pub fused: bool,
    /// GD epochs between *dynamic* gap-safe re-fires inside the inner
    /// solve (`--rule ssr-gapsafe`); `0` disables the mid-solve prunes.
    /// Ignored by static rules.
    pub rescreen_every: usize,
    /// Crash-resume checkpoint file (`--checkpoint`); `None` disables.
    pub checkpoint: Option<std::path::PathBuf>,
    /// Screening-scan precision (`--precision` / `HSSR_PRECISION`):
    /// [`Precision::F32`] lets the dynamic group gap-safe rule prefilter
    /// group norms with f32 scans widened by a proven error bound and
    /// confirm boundary groups exactly in f64 — selected group sets and
    /// coefficients are bit-identical to an all-f64 fit.
    pub precision: Precision,
}

impl Default for GroupPathConfig {
    fn default() -> Self {
        GroupPathConfig {
            rule: RuleKind::SsrBedpp,
            penalty: Penalty::Lasso,
            n_lambda: 100,
            lambda_min_ratio: 0.1,
            grid: GridKind::Linear,
            tol: 1e-7,
            max_iter: 100_000,
            lambdas: None,
            fused: fused_default(),
            rescreen_every: 10,
            checkpoint: None,
            precision: Precision::from_env(),
        }
    }
}

impl GroupPathConfig {
    /// Lower to the problem-independent driver configuration.
    fn driver(&self) -> DriverConfig {
        DriverConfig {
            rule: self.rule,
            n_lambda: self.n_lambda,
            lambda_min_ratio: self.lambda_min_ratio,
            grid: self.grid,
            lambdas: self.lambdas.clone(),
            fused: self.fused,
            checkpoint: self.checkpoint.clone(),
        }
    }
}

/// Result of a group-lasso path fit. Metrics reuse [`LambdaMetrics`] with
/// group counts in the set-size fields.
#[derive(Clone, Debug)]
pub struct GroupPathFit {
    /// λ grid.
    pub lambdas: Vec<f64>,
    /// Sparse coefficients per λ (column index, value) — columns of the
    /// *orthonormalized* design.
    pub betas: Vec<Vec<(usize, f64)>>,
    /// Per-λ instrumentation (group-level sizes).
    pub metrics: Vec<LambdaMetrics>,
    /// Total columns.
    pub p: usize,
    /// Number of groups.
    pub num_groups: usize,
    /// λmax.
    pub lambda_max: f64,
    /// Wall-clock seconds.
    pub seconds: f64,
    /// Strategy used.
    pub rule: RuleKind,
    /// `Some` when the path degraded gracefully (completed prefix only).
    pub error: Option<PathError>,
}

impl GroupPathFit {
    /// Dense coefficients at grid index `k`.
    pub fn beta_dense(&self, k: usize) -> Vec<f64> {
        let mut b = vec![0.0; self.p];
        for &(j, v) in &self.betas[k] {
            b[j] = v;
        }
        b
    }

    /// Number of active *groups* at grid index `k`.
    pub fn active_groups_at(&self, k: usize, ds: &GroupedDataset) -> usize {
        let b = self.beta_dense(k);
        (0..ds.num_groups())
            .filter(|&g| ds.layout.range(g).any(|j| b[j] != 0.0))
            .count()
    }

    /// Total columns scanned over the path (screening + KKT).
    pub fn total_cols_scanned(&self) -> u64 {
        self.metrics.iter().map(|m| m.cols_scanned).sum()
    }

    /// Total group KKT checks over the path.
    pub fn total_kkt_checks(&self) -> u64 {
        self.metrics.iter().map(|m| m.kkt_checked as u64).sum()
    }
}

/// The group-lasso problem as a [`Problem`] instance: the screening unit
/// is the *group*, the inner optimizer is blockwise group descent, lazy
/// state is `znorm_g = ‖X_gᵀr‖/n`, and the KKT threshold carries the
/// `√W_g` group-size weight (rule (21)).
pub struct GroupLassoProblem<'a> {
    x: &'a DenseMatrix,
    layout: &'a GroupLayout,
    engine: &'a dyn ScanEngine,
    rule: RuleKind,
    penalty: Penalty,
    tol: f64,
    max_iter: usize,
    rescreen_every: usize,
    ctx: GroupSafeContext,
    safe_rule: Option<Box<dyn SafeRule<GroupSafeContext>>>,
    beta: Vec<f64>,
    r: Vec<f64>,
    // znorm_g = ‖X_gᵀr/n‖ at the most recent residual it was computed at.
    znorm: Vec<f64>,
    znorm_valid: Vec<bool>,
}

impl<'a> GroupLassoProblem<'a> {
    /// Build the problem: validate the strategy, run the `O(np)`
    /// group-context precompute, start cold with norms seeded from the
    /// null residual `r = y`.
    pub fn new(
        ds: &'a GroupedDataset,
        cfg: &GroupPathConfig,
        engine: &'a dyn ScanEngine,
    ) -> Result<Self> {
        match cfg.rule {
            RuleKind::BasicPcd
            | RuleKind::ActiveCycling
            | RuleKind::Ssr
            | RuleKind::Sedpp
            | RuleKind::SsrBedpp
            | RuleKind::SsrGapSafe => {}
            other => {
                return Err(HssrError::Config(format!(
                    "group lasso supports Basic GD/AC/SSR/SEDPP/SSR-BEDPP/SSR-GapSafe, \
                     not {other:?}"
                )))
            }
        }
        cfg.penalty.validate()?;
        let x = &ds.x;
        let n = ds.n();
        let layout = &ds.layout;
        let g_count = layout.num_groups();
        let ctx = GroupSafeContext::build(x, &ds.y, layout, cfg.penalty);
        // initial residual = y: znorm from ctx.group_xty_sq
        let mut znorm = vec![0.0f64; g_count];
        for g in 0..g_count {
            znorm[g] = ctx.group_xty_sq[g].sqrt() / n as f64;
        }
        Ok(GroupLassoProblem {
            x,
            layout,
            engine,
            rule: cfg.rule,
            penalty: cfg.penalty,
            tol: cfg.tol,
            max_iter: cfg.max_iter,
            rescreen_every: cfg.rescreen_every,
            safe_rule: {
                let mut rule = make_group_safe_rule(cfg.rule);
                if let Some(r) = rule.as_mut() {
                    r.set_precision(cfg.precision);
                }
                rule
            },
            beta: vec![0.0f64; ds.p()],
            r: ds.y.clone(),
            znorm,
            znorm_valid: vec![true; g_count],
            ctx,
        })
    }

    /// Whether the attached safe rule is dynamic (gap-safe).
    fn dynamic_rule(&self) -> bool {
        self.safe_rule.as_ref().map(|r| r.dynamic()).unwrap_or(false)
    }

    /// Materialize safe discards of still-live groups (the group analogue
    /// of `GaussianLasso::zero_discarded`): zero the block, return its
    /// contribution to the residual, invalidate the lazy norms.
    fn zero_discarded(&mut self, survive: &[bool]) {
        let (x, layout, beta, r) = (self.x, self.layout, &mut self.beta, &mut self.r);
        let changed = zero_discarded_units(survive, |g| {
            let mut moved = false;
            for j in layout.range(g) {
                if beta[j] != 0.0 {
                    let b = beta[j];
                    ops::axpy(b, x.col(j), r);
                    beta[j] = 0.0;
                    moved = true;
                }
            }
            moved
        });
        if changed {
            self.znorm_valid.iter_mut().for_each(|v| *v = false);
        }
    }
}

/// [`BurstProblem`] view of [`GroupLassoProblem`] at one λ — the shared
/// [`dynamic_burst_solve`] drives GD bursts and gap-safe prunes through it.
struct GroupBurst<'p, 'a> {
    prob: &'p mut GroupLassoProblem<'a>,
    lam: f64,
}

impl BurstProblem for GroupBurst<'_, '_> {
    fn cycle(&mut self, work: &[usize], m: &mut LambdaMetrics) -> Result<f64> {
        let p = &mut *self.prob;
        m.coord_updates += work.iter().map(|&g| p.layout.sizes[g] as u64).sum::<u64>();
        let mut cols = ColSource::for_engine(p.engine, p.x);
        gd::gd_cycle_on(
            &mut cols,
            p.penalty,
            self.lam,
            work,
            &p.layout.starts,
            &p.layout.sizes,
            &mut p.beta,
            &mut p.r,
        )
    }

    fn rescreen_keep(&mut self, keep: &mut [bool], m: &mut LambdaMetrics) -> Result<()> {
        let p = &mut *self.prob;
        if let Some(rule) = p.safe_rule.as_mut() {
            let prev = PrevSolution { lambda: self.lam, r: &p.r, beta: Some(&p.beta) };
            let mut scanned = 0u64;
            rule.screen_routed(p.engine, p.x, &p.ctx, &prev, self.lam, keep, &mut scanned)?;
            m.cols_scanned += scanned;
        }
        Ok(())
    }

    fn evict(&mut self, g: usize) {
        let p = &mut *self.prob;
        for j in p.layout.range(g) {
            if p.beta[j] != 0.0 {
                let b = p.beta[j];
                ops::axpy(b, p.x.col(j), &mut p.r);
                p.beta[j] = 0.0;
            }
        }
    }
}

impl Problem for GroupLassoProblem<'_> {
    fn n_units(&self) -> usize {
        self.layout.num_groups()
    }

    fn n_coef(&self) -> usize {
        self.beta.len()
    }

    fn lambda_max(&self) -> f64 {
        self.ctx.lambda_max
    }

    fn has_safe_rule(&self) -> bool {
        self.safe_rule.is_some()
    }

    fn needs_kkt(&self) -> bool {
        !matches!(self.rule, RuleKind::BasicPcd | RuleKind::Sedpp)
    }

    fn io_counters(&self) -> Option<&crate::data::store::StoreCounters> {
        self.engine.column_store().map(|s| s.counters())
    }

    /// λ-ahead prefetch at group granularity: a group is predicted for
    /// λ_{k+1} if it is active or its lazy norm clears the group-SSR
    /// threshold `√W_g·α(2λ_{k+1} − λ_k)`; the prediction expands to the
    /// member columns. Overlap only, never correctness.
    fn prefetch_next(&mut self, lam: f64, lam_next: Option<f64>) {
        let Some(lam_next) = lam_next else { return };
        if self.engine.column_store().is_none() {
            return;
        }
        let t = crate::screening::ssr::threshold(self.penalty, lam_next, lam);
        let layout = self.layout;
        let mut cols = Vec::new();
        for g in 0..layout.num_groups() {
            let active = layout.range(g).any(|j| self.beta[j] != 0.0);
            let predicted = self.znorm_valid[g]
                && self.znorm[g] >= (layout.sizes[g] as f64).sqrt() * t;
            if active || predicted {
                cols.extend(layout.range(g));
            }
        }
        self.engine.prefetch_columns(&cols);
    }

    fn screen(
        &mut self,
        lam: f64,
        lam_prev: f64,
        run_safe: bool,
        fused: bool,
        survive: &mut [bool],
        m: &mut LambdaMetrics,
    ) -> Result<ScreenStage> {
        let layout = self.layout;
        let g_count = layout.num_groups();
        let uses_ssr = self.rule.uses_ssr();
        let mut stage =
            ScreenStage { dynamic: self.dynamic_rule(), ..ScreenStage::default() };

        if fused && uses_ssr {
            // ---- fused group screening: one pass applies the per-group
            // safe predicate, refreshes stale norms, and classifies ----
            let ssr_t = crate::screening::ssr::threshold(self.penalty, lam, lam_prev);
            let mut masked_d = 0usize;
            let mut rule_scanned = 0u64;
            let (fout, was_pointwise) = {
                let keep = if !run_safe {
                    None
                } else if let Some(rule) = self.safe_rule.as_mut() {
                    let prev =
                        PrevSolution { lambda: lam_prev, r: &self.r, beta: Some(&self.beta) };
                    rule.plan_routed(
                        self.engine,
                        self.x,
                        &self.ctx,
                        &prev,
                        lam,
                        survive,
                        &mut masked_d,
                        &mut rule_scanned,
                    )?
                } else {
                    None
                };
                let wp = keep.is_some();
                let out = self.engine.fused_group_screen(
                    self.x,
                    &self.r,
                    &layout.starts,
                    &layout.sizes,
                    keep.as_deref(),
                    ssr_t,
                    survive,
                    &mut self.znorm,
                    &mut self.znorm_valid,
                )?;
                (out, wp)
            };
            m.cols_scanned += rule_scanned;
            stage.discarded = masked_d + fout.discarded;
            stage.rule_dead = !was_pointwise
                && self.safe_rule.as_ref().map(|ru| ru.dead()).unwrap_or(false);
            m.safe_size = fout.safe_size;
            m.cols_scanned += fout.cols_scanned;
            stage.strong = fout.strong;
            self.zero_discarded(survive);
            return Ok(stage);
        }

        // ---- unfused screening (group level) ----
        if run_safe {
            if let Some(rule) = self.safe_rule.as_mut() {
                let prev =
                    PrevSolution { lambda: lam_prev, r: &self.r, beta: Some(&self.beta) };
                let mut scanned = 0u64;
                stage.discarded = rule.screen_routed(
                    self.engine,
                    self.x,
                    &self.ctx,
                    &prev,
                    lam,
                    survive,
                    &mut scanned,
                )?;
                m.cols_scanned += scanned;
                stage.rule_dead = rule.dead();
            }
        }
        m.safe_size = survive.iter().filter(|&&s| s).count();

        // refresh znorm over newly-entered safe groups (one pooled kernel)
        if uses_ssr {
            let stale: Vec<usize> =
                (0..g_count).filter(|&g| survive[g] && !self.znorm_valid[g]).collect();
            if !stale.is_empty() {
                m.cols_scanned += self.engine.group_norms(
                    self.x,
                    &self.r,
                    &layout.starts,
                    &layout.sizes,
                    &stale,
                    &mut self.znorm,
                    &mut self.znorm_valid,
                )?;
            }
        }

        // ---- strong set (groups) ----
        stage.strong = match self.rule {
            RuleKind::BasicPcd => (0..g_count).collect(),
            RuleKind::ActiveCycling => (0..g_count)
                .filter(|&g| layout.range(g).any(|j| self.beta[j] != 0.0))
                .collect(),
            RuleKind::Sedpp => (0..g_count).filter(|&g| survive[g]).collect(),
            _ => crate::screening::ssr::group_strong_set(
                self.penalty,
                lam,
                lam_prev,
                &self.znorm,
                &layout.sizes,
                survive,
            ),
        };
        self.zero_discarded(survive);
        Ok(stage)
    }

    fn solve(
        &mut self,
        lam: f64,
        lambda_index: usize,
        strong: &[usize],
        m: &mut LambdaMetrics,
    ) -> Result<()> {
        let dynamic = self.rescreen_every > 0 && self.dynamic_rule();
        if !dynamic {
            // Blockwise GD over the engine's column source: resident
            // natively, pinned store cursor out-of-core (diskless fit).
            let mut cols = ColSource::for_engine(self.engine, self.x);
            let stats = gd::gd_solve_on(
                &mut cols,
                self.penalty,
                lam,
                strong,
                &self.layout.starts,
                &self.layout.sizes,
                &mut self.beta,
                &mut self.r,
                self.tol,
                self.max_iter,
                lambda_index,
            )?;
            m.cd_cycles += stats.cycles;
            m.coord_updates += stats.coord_updates;
            if stats.cycles > 0 {
                self.znorm_valid.iter_mut().for_each(|v| *v = false);
            }
            return Ok(());
        }
        // Dynamic (gap-safe) solve: the shared burst driver runs GD in
        // bounded bursts with gap-safe prunes of the working group set in
        // between (see the lasso driver).
        let (rescreen_every, max_iter, tol, n_units) =
            (self.rescreen_every, self.max_iter, self.tol, self.layout.num_groups());
        let ran = dynamic_burst_solve(
            &mut GroupBurst { prob: self, lam },
            strong,
            n_units,
            rescreen_every,
            max_iter,
            tol,
            lambda_index,
            m,
        )?;
        if ran {
            self.znorm_valid.iter_mut().for_each(|v| *v = false);
        }
        Ok(())
    }

    fn rescreen(
        &mut self,
        lam: f64,
        survive: &mut [bool],
        in_strong: &[bool],
        m: &mut LambdaMetrics,
    ) -> Result<usize> {
        if !self.dynamic_rule() {
            return Ok(0);
        }
        let mut mask = survive.to_vec();
        if let Some(rule) = self.safe_rule.as_mut() {
            let prev = PrevSolution { lambda: lam, r: &self.r, beta: Some(&self.beta) };
            let mut scanned = 0u64;
            rule.screen_routed(
                self.engine,
                self.x,
                &self.ctx,
                &prev,
                lam,
                &mut mask,
                &mut scanned,
            )?;
            m.cols_scanned += scanned;
        }
        let (layout, beta) = (self.layout, &self.beta);
        Ok(apply_rescreen_mask(survive, &mask, in_strong, |g| {
            layout.range(g).any(|j| beta[j] != 0.0)
        }))
    }

    fn kkt(
        &mut self,
        lam: f64,
        fused: bool,
        survive: &[bool],
        in_strong: &[bool],
        m: &mut LambdaMetrics,
    ) -> Result<Vec<usize>> {
        let layout = self.layout;
        if fused {
            // One traversal: group norms + KKT test. Strong groups are
            // not refreshed here — the residual is unchanged until the
            // next λ's screening, which lazily refreshes them as stale
            // with bit-identical norms (see the lasso driver).
            let penalty = self.penalty;
            let violates =
                move |g: usize, zn: f64| kkt::group_violates(penalty, lam, layout.sizes[g], zn);
            let fout = self.engine.fused_group_kkt(
                self.x,
                &self.r,
                &layout.starts,
                &layout.sizes,
                survive,
                in_strong,
                &violates,
                false,
                &mut self.znorm,
                &mut self.znorm_valid,
            )?;
            m.cols_scanned += fout.cols_scanned;
            m.kkt_checked += fout.checked;
            return Ok(fout.violations);
        }
        let g_count = layout.num_groups();
        let check: Vec<usize> =
            (0..g_count).filter(|&g| survive[g] && !in_strong[g]).collect();
        if check.is_empty() {
            return Ok(Vec::new());
        }
        m.cols_scanned += self.engine.group_norms(
            self.x,
            &self.r,
            &layout.starts,
            &layout.sizes,
            &check,
            &mut self.znorm,
            &mut self.znorm_valid,
        )?;
        m.kkt_checked += check.len();
        let zsub: Vec<f64> = check.iter().map(|&g| self.znorm[g]).collect();
        Ok(kkt::group_violations(self.penalty, lam, &check, &zsub, &layout.sizes))
    }

    fn end_lambda(
        &mut self,
        _lam: f64,
        fused: bool,
        strong: &[usize],
        m: &mut LambdaMetrics,
    ) -> Result<()> {
        // Unfused driver: refresh norms over the strong groups for the next
        // screening (the fused pass leaves them lazily refreshable).
        let use_fused_kkt = fused && self.needs_kkt();
        if !use_fused_kkt && self.rule.uses_ssr() && !strong.is_empty() {
            m.cols_scanned += self.engine.group_norms(
                self.x,
                &self.r,
                &self.layout.starts,
                &self.layout.sizes,
                strong,
                &mut self.znorm,
                &mut self.znorm_valid,
            )?;
        }
        Ok(())
    }

    fn sparse_beta(&self) -> Vec<(usize, f64)> {
        (0..self.beta.len())
            .filter(|&j| self.beta[j] != 0.0)
            .map(|j| (j, self.beta[j]))
            .collect()
    }

    fn objective(&self, lam: f64) -> f64 {
        // group elastic-net objective (lasso when α = 1)
        let layout = self.layout;
        let mut pen = 0.0;
        let mut l2 = 0.0;
        for g in 0..layout.num_groups() {
            let ss: f64 = layout.range(g).map(|j| self.beta[j] * self.beta[j]).sum();
            pen += (layout.sizes[g] as f64).sqrt() * ss.sqrt();
            l2 += ss;
        }
        ops::nrm2_sq(&self.r) / (2.0 * self.ctx.n as f64)
            + self.penalty.alpha() * lam * pen
            + self.penalty.l2_weight() * lam * 0.5 * l2
    }

    /// Group analogue of the lasso checkpoint state: β, the residual, the
    /// lazy group norms with their validity mask (serialized exactly so a
    /// resumed fit reproduces `cols_scanned` bit-for-bit), and the safe
    /// rule's phase state.
    fn save_state(&self) -> Option<Vec<u8>> {
        let mut w = ByteWriter::new();
        w.put_f64s(&self.beta);
        w.put_f64s(&self.r);
        w.put_f64s(&self.znorm);
        w.put_bools(&self.znorm_valid);
        let rule_state =
            self.safe_rule.as_ref().map(|ru| ru.save_state()).unwrap_or_default();
        w.put_blob(&rule_state);
        Some(w.into_bytes())
    }

    fn restore_state(&mut self, state: &[u8]) -> Result<()> {
        let mut rd = ByteReader::new(state);
        let beta = rd.get_f64s()?;
        let r = rd.get_f64s()?;
        let znorm = rd.get_f64s()?;
        let znorm_valid = rd.get_bools()?;
        let rule_state = rd.get_blob()?.to_vec();
        let g_count = self.layout.num_groups();
        if beta.len() != self.beta.len()
            || r.len() != self.r.len()
            || znorm.len() != g_count
            || znorm_valid.len() != g_count
        {
            return Err(HssrError::Corrupt(
                "group-lasso checkpoint state dimensions do not match the data".into(),
            ));
        }
        if let Some(rule) = self.safe_rule.as_mut() {
            rule.load_state(&rule_state)?;
        }
        self.beta = beta;
        self.r = r;
        self.znorm = znorm;
        self.znorm_valid = znorm_valid;
        Ok(())
    }
}

/// Fit with the default engine: native (pool-backed), or an out-of-core
/// spill engine when `HSSR_ENGINE=ooc` (see
/// [`crate::runtime::ooc::env_engine_for`]).
pub fn fit_group_path(ds: &GroupedDataset, cfg: &GroupPathConfig) -> Result<GroupPathFit> {
    if let Some(engine) = ooc::env_engine_for(&ds.x, &ds.y)? {
        return fit_group_path_with_engine(ds, cfg, &engine);
    }
    fit_group_path_with_engine(ds, cfg, &NativeEngine::new())
}

/// Fit with an explicit scan engine.
pub fn fit_group_path_with_engine(
    ds: &GroupedDataset,
    cfg: &GroupPathConfig,
    engine: &dyn ScanEngine,
) -> Result<GroupPathFit> {
    let mut prob = GroupLassoProblem::new(ds, cfg, engine)?;
    let fit = drive(&mut prob, &cfg.driver())?;
    Ok(GroupPathFit {
        lambdas: fit.lambdas,
        betas: fit.betas,
        metrics: fit.metrics,
        p: fit.p,
        num_groups: ds.num_groups(),
        lambda_max: fit.lambda_max,
        seconds: fit.seconds,
        rule: fit.rule,
        error: fit.error,
    })
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::data::synth::generate_grouped;

    fn small_cfg(rule: RuleKind) -> GroupPathConfig {
        GroupPathConfig { rule, n_lambda: 25, tol: 1e-9, ..GroupPathConfig::default() }
    }

    fn max_beta_diff(a: &GroupPathFit, b: &GroupPathFit) -> f64 {
        let mut worst = 0.0f64;
        for k in 0..a.lambdas.len() {
            let da = a.beta_dense(k);
            let db = b.beta_dense(k);
            for j in 0..da.len() {
                worst = worst.max((da[j] - db[j]).abs());
            }
        }
        worst
    }

    /// Theorem 3.1 for the group lasso: all strategies agree.
    #[test]
    fn all_rules_agree() {
        let ds = generate_grouped(90, 15, 4, 4, 11);
        let base = fit_group_path(&ds, &small_cfg(RuleKind::BasicPcd)).unwrap();
        for rule in [
            RuleKind::ActiveCycling,
            RuleKind::Ssr,
            RuleKind::Sedpp,
            RuleKind::SsrBedpp,
            RuleKind::SsrGapSafe,
        ] {
            let fit = fit_group_path(&ds, &small_cfg(rule)).unwrap();
            let d = max_beta_diff(&base, &fit);
            assert!(d < 1e-5, "{rule:?} deviates by {d}");
        }
    }

    /// The fused group driver must match the unfused one bit-for-bit.
    #[test]
    fn fused_group_driver_bit_identical_to_unfused() {
        let ds = generate_grouped(80, 20, 4, 4, 15);
        for rule in [
            RuleKind::BasicPcd,
            RuleKind::ActiveCycling,
            RuleKind::Ssr,
            RuleKind::Sedpp,
            RuleKind::SsrBedpp,
            RuleKind::SsrGapSafe,
        ] {
            let fused = fit_group_path(
                &ds,
                &GroupPathConfig { fused: true, ..small_cfg(rule) },
            )
            .unwrap();
            let unfused = fit_group_path(
                &ds,
                &GroupPathConfig { fused: false, ..small_cfg(rule) },
            )
            .unwrap();
            assert_eq!(fused.betas, unfused.betas, "{rule:?} betas differ");
            for (k, (mf, mu)) in
                fused.metrics.iter().zip(unfused.metrics.iter()).enumerate()
            {
                assert_eq!(mf.safe_size, mu.safe_size, "{rule:?} |S| at λ#{k}");
                assert_eq!(mf.strong_size, mu.strong_size, "{rule:?} |H| at λ#{k}");
                assert_eq!(mf.violations, mu.violations, "{rule:?} viols at λ#{k}");
            }
        }
    }

    #[test]
    fn unsupported_rules_rejected() {
        let ds = generate_grouped(30, 4, 3, 1, 1);
        let err = fit_group_path(&ds, &small_cfg(RuleKind::SsrDome)).unwrap_err();
        assert!(matches!(err, HssrError::Config(_)));
    }

    #[test]
    fn invalid_alpha_rejected() {
        let ds = generate_grouped(30, 4, 3, 1, 1);
        let cfg = GroupPathConfig {
            penalty: Penalty::ElasticNet { alpha: 0.0 },
            ..small_cfg(RuleKind::SsrBedpp)
        };
        assert!(matches!(fit_group_path(&ds, &cfg), Err(HssrError::Config(_))));
    }

    fn enet_cfg(rule: RuleKind, alpha: f64) -> GroupPathConfig {
        GroupPathConfig {
            penalty: Penalty::ElasticNet { alpha },
            ..small_cfg(rule)
        }
    }

    /// Theorem 3.1 for the group elastic net: all strategies agree.
    #[test]
    fn enet_all_rules_agree() {
        let ds = generate_grouped(90, 15, 4, 4, 21);
        let base = fit_group_path(&ds, &enet_cfg(RuleKind::BasicPcd, 0.7)).unwrap();
        for rule in [
            RuleKind::ActiveCycling,
            RuleKind::Ssr,
            RuleKind::Sedpp,
            RuleKind::SsrBedpp,
            RuleKind::SsrGapSafe,
        ] {
            let fit = fit_group_path(&ds, &enet_cfg(rule, 0.7)).unwrap();
            let d = max_beta_diff(&base, &fit);
            assert!(d < 1e-5, "enet {rule:?} deviates by {d}");
        }
    }

    /// The fused group-enet driver must match the unfused one bit-for-bit.
    #[test]
    fn enet_fused_group_driver_bit_identical_to_unfused() {
        let ds = generate_grouped(80, 20, 4, 4, 22);
        for rule in [
            RuleKind::BasicPcd,
            RuleKind::ActiveCycling,
            RuleKind::Ssr,
            RuleKind::Sedpp,
            RuleKind::SsrBedpp,
            RuleKind::SsrGapSafe,
        ] {
            let cfg = GroupPathConfig { fused: true, ..enet_cfg(rule, 0.55) };
            let fused = fit_group_path(&ds, &cfg).unwrap();
            let unfused =
                fit_group_path(&ds, &GroupPathConfig { fused: false, ..cfg }).unwrap();
            assert_eq!(fused.betas, unfused.betas, "enet {rule:?} betas differ");
            for (k, (mf, mu)) in
                fused.metrics.iter().zip(unfused.metrics.iter()).enumerate()
            {
                assert_eq!(mf.safe_size, mu.safe_size, "enet {rule:?} |S| at λ#{k}");
                assert_eq!(mf.strong_size, mu.strong_size, "enet {rule:?} |H| at λ#{k}");
                assert_eq!(mf.violations, mu.violations, "enet {rule:?} viols at λ#{k}");
            }
        }
    }

    /// Group elastic-net KKT at the solution: inactive groups satisfy
    /// ‖X_gᵀr/n‖ ≤ αλ√W_g; active groups X_gᵀr/n = αλ√W_g·β_g/‖β_g‖
    /// + (1−α)λ·β_g.
    #[test]
    fn enet_group_kkt_holds_along_path() {
        let ds = generate_grouped(80, 10, 3, 3, 23);
        let alpha = 0.6;
        let fit = fit_group_path(&ds, &enet_cfg(RuleKind::SsrBedpp, alpha)).unwrap();
        let n = ds.n() as f64;
        for (k, &lam) in fit.lambdas.iter().enumerate().step_by(6) {
            let b = fit.beta_dense(k);
            let f = ds.x.matvec(&b);
            let r: Vec<f64> = ds.y.iter().zip(&f).map(|(y, v)| y - v).collect();
            for g in 0..ds.num_groups() {
                let zg: Vec<f64> = ds
                    .layout
                    .range(g)
                    .map(|j| ops::dot(ds.x.col(j), &r) / n)
                    .collect();
                let bg: Vec<f64> = ds.layout.range(g).map(|j| b[j]).collect();
                let bnorm = ops::nrm2(&bg);
                let w_sqrt = (ds.layout.sizes[g] as f64).sqrt();
                if bnorm == 0.0 {
                    let zn = ops::nrm2(&zg);
                    assert!(
                        zn <= alpha * lam * w_sqrt * (1.0 + 1e-3) + 1e-8,
                        "enet inactive λ#{k} group {g}: {zn}"
                    );
                } else {
                    for (i, (&z, &bj)) in zg.iter().zip(&bg).enumerate() {
                        let want =
                            alpha * lam * w_sqrt * bj / bnorm + (1.0 - alpha) * lam * bj;
                        assert!(
                            (z - want).abs() < 1e-5,
                            "enet active λ#{k} group {g} coord {i}"
                        );
                    }
                }
            }
        }
    }

    /// λmax for the group enet scales by 1/α and β(λmax) = 0.
    #[test]
    fn enet_zero_solution_at_lambda_max() {
        let ds = generate_grouped(60, 8, 3, 2, 24);
        let lasso = fit_group_path(&ds, &small_cfg(RuleKind::SsrBedpp)).unwrap();
        let enet = fit_group_path(&ds, &enet_cfg(RuleKind::SsrBedpp, 0.5)).unwrap();
        assert!((enet.lambda_max - 2.0 * lasso.lambda_max).abs() < 1e-10);
        assert_eq!(enet.betas[0].len(), 0);
        assert!(enet.betas.last().unwrap().len() > 0);
    }

    #[test]
    fn zero_solution_at_lambda_max() {
        let ds = generate_grouped(60, 8, 3, 2, 12);
        let fit = fit_group_path(&ds, &small_cfg(RuleKind::SsrBedpp)).unwrap();
        assert_eq!(fit.betas[0].len(), 0);
        assert!(fit.betas.last().unwrap().len() > 0);
    }

    #[test]
    fn group_kkt_holds_along_path() {
        let ds = generate_grouped(80, 10, 3, 3, 13);
        let fit = fit_group_path(&ds, &small_cfg(RuleKind::SsrBedpp)).unwrap();
        let n = ds.n() as f64;
        for (k, &lam) in fit.lambdas.iter().enumerate().step_by(6) {
            let b = fit.beta_dense(k);
            let f = ds.x.matvec(&b);
            let r: Vec<f64> = ds.y.iter().zip(&f).map(|(y, v)| y - v).collect();
            for g in 0..ds.num_groups() {
                let zn = {
                    let mut ss = 0.0;
                    for j in ds.layout.range(g) {
                        let d = ops::dot(ds.x.col(j), &r) / n;
                        ss += d * d;
                    }
                    ss.sqrt()
                };
                let active = ds.layout.range(g).any(|j| b[j] != 0.0);
                let w_sqrt = (ds.layout.sizes[g] as f64).sqrt();
                if !active {
                    assert!(zn <= lam * w_sqrt * (1.0 + 1e-3) + 1e-8, "λ#{k} group {g}");
                }
            }
        }
    }

    /// Crash-resume for the group family: kill after k λs, resume from the
    /// checkpoint, and the result must be bit-identical to an uninterrupted
    /// fit (βs, metrics, group-norm scan accounting).
    #[test]
    fn checkpoint_resume_is_bit_identical() {
        let dir = std::env::temp_dir().join("hssr_group_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let ck = dir.join("group.ckpt");
        let _ = std::fs::remove_file(&ck);
        let ds = generate_grouped(70, 12, 4, 3, 19);
        let full = fit_group_path(&ds, &small_cfg(RuleKind::SsrBedpp)).unwrap();
        let grid = full.lambdas.clone();
        let prefix_cfg = GroupPathConfig {
            lambdas: Some(grid[..9].to_vec()),
            checkpoint: Some(ck.clone()),
            ..small_cfg(RuleKind::SsrBedpp)
        };
        fit_group_path(&ds, &prefix_cfg).unwrap();
        let resume_cfg = GroupPathConfig {
            lambdas: Some(grid.clone()),
            checkpoint: Some(ck.clone()),
            ..small_cfg(RuleKind::SsrBedpp)
        };
        let resumed = fit_group_path(&ds, &resume_cfg).unwrap();
        assert_eq!(resumed.betas, full.betas, "group betas differ after resume");
        for (k, (ma, mb)) in full.metrics.iter().zip(resumed.metrics.iter()).enumerate()
        {
            assert_eq!(ma, mb, "group metrics at λ#{k}");
        }
        let _ = std::fs::remove_file(&ck);
    }

    #[test]
    fn hssr_scans_fewer_group_columns_than_ssr() {
        let ds = generate_grouped(80, 60, 5, 5, 14);
        let ssr = fit_group_path(&ds, &small_cfg(RuleKind::Ssr)).unwrap();
        let hssr = fit_group_path(&ds, &small_cfg(RuleKind::SsrBedpp)).unwrap();
        assert!(hssr.total_cols_scanned() <= ssr.total_cols_scanned());
    }
}
