//! Pathwise group descent with screening — Algorithm 1 adapted to the group
//! lasso (paper §4.2 and §5.2). Methods: Basic GD, AC, SSR, SEDPP, and
//! SSR-BEDPP (Table 3).
//!
//! Like the lasso driver, the default execution is **fused**: group-norm
//! refreshes go through [`ScanEngine::group_norms`] (one pool-parallel
//! kernel over the stale groups instead of a scan per group), and the
//! post-convergence check goes through [`ScanEngine::fused_group_kkt`] —
//! one traversal recomputing `‖X_gᵀr‖/n` per surviving group, testing KKT
//! for non-strong groups, and doubling as the end-of-step strong refresh.
//! `fused: false` retains the separate-traversal driver; both select
//! identical group sets.

use std::time::Instant;

use crate::data::GroupedDataset;
use crate::error::{HssrError, Result};
use crate::linalg::ops;
use crate::runtime::{native::NativeEngine, ScanEngine};
use crate::screening::group::{GroupBedpp, GroupSafeContext, GroupSafeRule, GroupSedpp};
use crate::screening::{PrevSolution, RuleKind};
use crate::solver::lambda::GridKind;
use crate::solver::path::LambdaMetrics;
use crate::solver::{gd, kkt};

/// Configuration for a group-lasso path fit.
#[derive(Clone, Debug)]
pub struct GroupPathConfig {
    /// Strategy — one of `BasicPcd` (reported as "Basic GD"), `ActiveCycling`,
    /// `Ssr`, `Sedpp`, `SsrBedpp`.
    pub rule: RuleKind,
    /// Number of λ grid points.
    pub n_lambda: usize,
    /// Smallest λ as a fraction of λmax.
    pub lambda_min_ratio: f64,
    /// Grid spacing.
    pub grid: GridKind,
    /// Convergence tolerance.
    pub tol: f64,
    /// Max group-descent cycles per λ per round.
    pub max_iter: usize,
    /// Explicit grid override.
    pub lambdas: Option<Vec<f64>>,
    /// Drive the fused group-norm/KKT pipeline (default; see module docs).
    pub fused: bool,
}

impl Default for GroupPathConfig {
    fn default() -> Self {
        GroupPathConfig {
            rule: RuleKind::SsrBedpp,
            n_lambda: 100,
            lambda_min_ratio: 0.1,
            grid: GridKind::Linear,
            tol: 1e-7,
            max_iter: 100_000,
            lambdas: None,
            fused: true,
        }
    }
}

/// Result of a group-lasso path fit. Metrics reuse [`LambdaMetrics`] with
/// group counts in the set-size fields.
#[derive(Clone, Debug)]
pub struct GroupPathFit {
    /// λ grid.
    pub lambdas: Vec<f64>,
    /// Sparse coefficients per λ (column index, value) — columns of the
    /// *orthonormalized* design.
    pub betas: Vec<Vec<(usize, f64)>>,
    /// Per-λ instrumentation (group-level sizes).
    pub metrics: Vec<LambdaMetrics>,
    /// Total columns.
    pub p: usize,
    /// Number of groups.
    pub num_groups: usize,
    /// λmax.
    pub lambda_max: f64,
    /// Wall-clock seconds.
    pub seconds: f64,
    /// Strategy used.
    pub rule: RuleKind,
}

impl GroupPathFit {
    /// Dense coefficients at grid index `k`.
    pub fn beta_dense(&self, k: usize) -> Vec<f64> {
        let mut b = vec![0.0; self.p];
        for &(j, v) in &self.betas[k] {
            b[j] = v;
        }
        b
    }

    /// Number of active *groups* at grid index `k`.
    pub fn active_groups_at(&self, k: usize, ds: &GroupedDataset) -> usize {
        let b = self.beta_dense(k);
        (0..ds.num_groups())
            .filter(|&g| ds.layout.range(g).any(|j| b[j] != 0.0))
            .count()
    }

    /// Total columns scanned over the path (screening + KKT).
    pub fn total_cols_scanned(&self) -> u64 {
        self.metrics.iter().map(|m| m.cols_scanned).sum()
    }

    /// Total group KKT checks over the path.
    pub fn total_kkt_checks(&self) -> u64 {
        self.metrics.iter().map(|m| m.kkt_checked as u64).sum()
    }
}

/// Fit with the default native (pool-backed) engine.
pub fn fit_group_path(ds: &GroupedDataset, cfg: &GroupPathConfig) -> Result<GroupPathFit> {
    fit_group_path_with_engine(ds, cfg, &NativeEngine::new())
}

/// Fit with an explicit scan engine.
pub fn fit_group_path_with_engine(
    ds: &GroupedDataset,
    cfg: &GroupPathConfig,
    engine: &dyn ScanEngine,
) -> Result<GroupPathFit> {
    let start = Instant::now();
    let x = &ds.x;
    let n = ds.n();
    let p = ds.p();
    let g_count = ds.num_groups();
    let layout = &ds.layout;
    let ctx = GroupSafeContext::build(x, &ds.y, layout);
    let lambdas = match &cfg.lambdas {
        Some(ls) => ls.clone(),
        None => crate::solver::lambda::grid(
            ctx.lambda_max,
            cfg.lambda_min_ratio,
            cfg.n_lambda,
            cfg.grid,
        ),
    };
    let mut safe_rule: Option<Box<dyn GroupSafeRule>> = match cfg.rule {
        RuleKind::SsrBedpp => Some(Box::new(GroupBedpp::new())),
        RuleKind::Sedpp => Some(Box::new(GroupSedpp::new())),
        RuleKind::BasicPcd | RuleKind::ActiveCycling | RuleKind::Ssr => None,
        other => {
            return Err(HssrError::Config(format!(
                "group lasso supports Basic GD/AC/SSR/SEDPP/SSR-BEDPP, not {other:?}"
            )))
        }
    };
    let uses_ssr = cfg.rule.uses_ssr();
    let use_fused_kkt =
        cfg.fused && !matches!(cfg.rule, RuleKind::BasicPcd | RuleKind::Sedpp);
    // ---- path state ----
    let mut beta = vec![0.0f64; p];
    let mut r = ds.y.clone();
    // znorm_g = ‖X_gᵀr/n‖ at the most recent residual it was computed at.
    let mut znorm = vec![0.0f64; g_count];
    let mut znorm_valid = vec![false; g_count];
    // initial residual = y: znorm from ctx.group_xty_sq
    for g in 0..g_count {
        znorm[g] = ctx.group_xty_sq[g].sqrt() / n as f64;
        znorm_valid[g] = true;
    }
    let mut flag_off = safe_rule.is_none();
    let mut betas = Vec::with_capacity(lambdas.len());
    let mut metrics = Vec::with_capacity(lambdas.len());

    let mut lam_prev = ctx.lambda_max;
    for (k, &lam) in lambdas.iter().enumerate() {
        let mut m = LambdaMetrics { lambda: lam, ..Default::default() };
        // ---- safe screening (group level) ----
        let mut survive = vec![true; g_count];
        if !flag_off {
            if let Some(rule) = safe_rule.as_mut() {
                let prev = PrevSolution { lambda: lam_prev, r: &r };
                let discarded = rule.screen(x, &ctx, &prev, lam, &mut survive);
                if discarded == 0 || rule.dead() {
                    flag_off = true;
                    survive.iter_mut().for_each(|s| *s = true);
                }
            }
        }
        m.safe_size = survive.iter().filter(|&&s| s).count();

        // refresh znorm over newly-entered safe groups (one pooled kernel)
        if uses_ssr {
            let stale: Vec<usize> =
                (0..g_count).filter(|&g| survive[g] && !znorm_valid[g]).collect();
            if !stale.is_empty() {
                m.cols_scanned += engine.group_norms(
                    x,
                    &r,
                    &layout.starts,
                    &layout.sizes,
                    &stale,
                    &mut znorm,
                    &mut znorm_valid,
                )?;
            }
        }

        // ---- strong set (groups) ----
        let mut strong: Vec<usize> = match cfg.rule {
            RuleKind::BasicPcd => (0..g_count).collect(),
            RuleKind::ActiveCycling => (0..g_count)
                .filter(|&g| layout.range(g).any(|j| beta[j] != 0.0))
                .collect(),
            RuleKind::Sedpp => (0..g_count).filter(|&g| survive[g]).collect(),
            _ => crate::screening::ssr::group_strong_set(
                lam,
                lam_prev,
                &znorm,
                &layout.sizes,
                &survive,
            ),
        };
        let mut in_strong = vec![false; g_count];
        for &g in &strong {
            in_strong[g] = true;
        }

        // ---- solve + KKT loop ----
        loop {
            let stats = gd::gd_solve(
                x,
                lam,
                &strong,
                &layout.starts,
                &layout.sizes,
                &mut beta,
                &mut r,
                cfg.tol,
                cfg.max_iter,
                k,
            )?;
            m.cd_cycles += stats.cycles;
            m.coord_updates += stats.coord_updates;
            if stats.cycles > 0 {
                znorm_valid.iter_mut().for_each(|v| *v = false);
            }
            if matches!(cfg.rule, RuleKind::BasicPcd | RuleKind::Sedpp) {
                break; // exact / safe ⇒ no group KKT checking
            }
            if use_fused_kkt {
                // One traversal: group norms + KKT test. Strong groups are
                // not refreshed here — the residual is unchanged until the
                // next λ's screening, which lazily refreshes them as stale
                // with bit-identical norms (see the lasso driver).
                let fout = engine.fused_group_kkt(
                    x,
                    &r,
                    &layout.starts,
                    &layout.sizes,
                    &survive,
                    &in_strong,
                    &|g: usize, zn: f64| kkt::group_violates(lam, layout.sizes[g], zn),
                    false,
                    &mut znorm,
                    &mut znorm_valid,
                )?;
                m.cols_scanned += fout.cols_scanned;
                m.kkt_checked += fout.checked;
                if fout.violations.is_empty() {
                    break;
                }
                m.violations += fout.violations.len();
                for &g in &fout.violations {
                    in_strong[g] = true;
                }
                strong.extend(fout.violations);
            } else {
                let check: Vec<usize> = match cfg.rule {
                    RuleKind::ActiveCycling | RuleKind::Ssr => {
                        (0..g_count).filter(|&g| !in_strong[g]).collect()
                    }
                    _ => {
                        (0..g_count).filter(|&g| survive[g] && !in_strong[g]).collect()
                    }
                };
                if check.is_empty() {
                    break;
                }
                m.cols_scanned += engine.group_norms(
                    x,
                    &r,
                    &layout.starts,
                    &layout.sizes,
                    &check,
                    &mut znorm,
                    &mut znorm_valid,
                )?;
                m.kkt_checked += check.len();
                let zsub: Vec<f64> = check.iter().map(|&g| znorm[g]).collect();
                let viols = kkt::group_violations(lam, &check, &zsub, &layout.sizes);
                if viols.is_empty() {
                    break;
                }
                m.violations += viols.len();
                for &g in &viols {
                    in_strong[g] = true;
                }
                strong.extend(viols);
            }
        }

        // Unfused driver: refresh norms over the strong groups for the next
        // screening (the fused pass already did in its final round).
        if !use_fused_kkt && uses_ssr && !strong.is_empty() {
            m.cols_scanned += engine.group_norms(
                x,
                &r,
                &layout.starts,
                &layout.sizes,
                &strong,
                &mut znorm,
                &mut znorm_valid,
            )?;
        }

        m.strong_size = strong.len();
        let sparse: Vec<(usize, f64)> =
            (0..p).filter(|&j| beta[j] != 0.0).map(|j| (j, beta[j])).collect();
        m.nonzero = sparse.len();
        // group-lasso objective
        let mut pen = 0.0;
        for g in 0..g_count {
            let ss: f64 = layout.range(g).map(|j| beta[j] * beta[j]).sum();
            pen += (layout.sizes[g] as f64).sqrt() * ss.sqrt();
        }
        m.objective = ops::nrm2_sq(&r) / (2.0 * n as f64) + lam * pen;
        betas.push(sparse);
        metrics.push(m);
        lam_prev = lam;
    }
    Ok(GroupPathFit {
        lambdas,
        betas,
        metrics,
        p,
        num_groups: g_count,
        lambda_max: ctx.lambda_max,
        seconds: start.elapsed().as_secs_f64(),
        rule: cfg.rule,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::generate_grouped;

    fn small_cfg(rule: RuleKind) -> GroupPathConfig {
        GroupPathConfig { rule, n_lambda: 25, tol: 1e-9, ..GroupPathConfig::default() }
    }

    fn max_beta_diff(a: &GroupPathFit, b: &GroupPathFit) -> f64 {
        let mut worst = 0.0f64;
        for k in 0..a.lambdas.len() {
            let da = a.beta_dense(k);
            let db = b.beta_dense(k);
            for j in 0..da.len() {
                worst = worst.max((da[j] - db[j]).abs());
            }
        }
        worst
    }

    /// Theorem 3.1 for the group lasso: all strategies agree.
    #[test]
    fn all_rules_agree() {
        let ds = generate_grouped(90, 15, 4, 4, 11);
        let base = fit_group_path(&ds, &small_cfg(RuleKind::BasicPcd)).unwrap();
        for rule in [
            RuleKind::ActiveCycling,
            RuleKind::Ssr,
            RuleKind::Sedpp,
            RuleKind::SsrBedpp,
        ] {
            let fit = fit_group_path(&ds, &small_cfg(rule)).unwrap();
            let d = max_beta_diff(&base, &fit);
            assert!(d < 1e-5, "{rule:?} deviates by {d}");
        }
    }

    /// The fused group driver must match the unfused one bit-for-bit.
    #[test]
    fn fused_group_driver_bit_identical_to_unfused() {
        let ds = generate_grouped(80, 20, 4, 4, 15);
        for rule in [
            RuleKind::BasicPcd,
            RuleKind::ActiveCycling,
            RuleKind::Ssr,
            RuleKind::Sedpp,
            RuleKind::SsrBedpp,
        ] {
            let fused = fit_group_path(&ds, &small_cfg(rule)).unwrap();
            let unfused = fit_group_path(
                &ds,
                &GroupPathConfig { fused: false, ..small_cfg(rule) },
            )
            .unwrap();
            assert_eq!(fused.betas, unfused.betas, "{rule:?} betas differ");
            for (k, (mf, mu)) in
                fused.metrics.iter().zip(unfused.metrics.iter()).enumerate()
            {
                assert_eq!(mf.safe_size, mu.safe_size, "{rule:?} |S| at λ#{k}");
                assert_eq!(mf.strong_size, mu.strong_size, "{rule:?} |H| at λ#{k}");
                assert_eq!(mf.violations, mu.violations, "{rule:?} viols at λ#{k}");
            }
        }
    }

    #[test]
    fn unsupported_rules_rejected() {
        let ds = generate_grouped(30, 4, 3, 1, 1);
        let err = fit_group_path(&ds, &small_cfg(RuleKind::SsrDome)).unwrap_err();
        assert!(matches!(err, HssrError::Config(_)));
    }

    #[test]
    fn zero_solution_at_lambda_max() {
        let ds = generate_grouped(60, 8, 3, 2, 12);
        let fit = fit_group_path(&ds, &small_cfg(RuleKind::SsrBedpp)).unwrap();
        assert_eq!(fit.betas[0].len(), 0);
        assert!(fit.betas.last().unwrap().len() > 0);
    }

    #[test]
    fn group_kkt_holds_along_path() {
        let ds = generate_grouped(80, 10, 3, 3, 13);
        let fit = fit_group_path(&ds, &small_cfg(RuleKind::SsrBedpp)).unwrap();
        let n = ds.n() as f64;
        for (k, &lam) in fit.lambdas.iter().enumerate().step_by(6) {
            let b = fit.beta_dense(k);
            let f = ds.x.matvec(&b);
            let r: Vec<f64> = ds.y.iter().zip(&f).map(|(y, v)| y - v).collect();
            for g in 0..ds.num_groups() {
                let zn = {
                    let mut ss = 0.0;
                    for j in ds.layout.range(g) {
                        let d = ops::dot(ds.x.col(j), &r) / n;
                        ss += d * d;
                    }
                    ss.sqrt()
                };
                let active = ds.layout.range(g).any(|j| b[j] != 0.0);
                let w_sqrt = (ds.layout.sizes[g] as f64).sqrt();
                if !active {
                    assert!(zn <= lam * w_sqrt * (1.0 + 1e-3) + 1e-8, "λ#{k} group {g}");
                }
            }
        }
    }

    #[test]
    fn hssr_scans_fewer_group_columns_than_ssr() {
        let ds = generate_grouped(80, 60, 5, 5, 14);
        let ssr = fit_group_path(&ds, &small_cfg(RuleKind::Ssr)).unwrap();
        let hssr = fit_group_path(&ds, &small_cfg(RuleKind::SsrBedpp)).unwrap();
        assert!(hssr.total_cols_scanned() <= ssr.total_cols_scanned());
    }
}
