//! The generic pathwise driver — **Algorithm 1** of the paper, written
//! once for every lasso-type problem family.
//!
//! The paper's central claim is that one hybrid screening skeleton
//! generalizes across the lasso/elastic net, the group lasso, and (§6)
//! sparse logistic regression. This module is that skeleton, factored out
//! of the three formerly-duplicated drivers:
//!
//! * [`drive`] owns the λ-grid walk, warm starts, the
//!   screen → optimize → KKT → violation-round loop, the safe-rule
//!   switch-off flag (`Flag`, Algorithm 1 lines 6–8), per-λ
//!   [`LambdaMetrics`], and the fused/unfused pipeline split;
//! * the [`Problem`] trait abstracts exactly what varies between problem
//!   families: the unit of screening (column vs. group), the inner
//!   optimizer (coordinate descent, blockwise group descent, IRLS-wrapped
//!   weighted CD), the residual / working-response update, and the KKT
//!   threshold (including the elastic-net α scaling).
//!
//! [`crate::solver::path::GaussianLasso`] (lasso + elastic net),
//! [`crate::solver::group_path::GroupLassoProblem`], and
//! [`crate::solver::logistic::LogisticProblem`] are the three `Problem`
//! instances; their `fit_*` entry points are thin shims that construct the
//! problem and call [`drive`]. Every engine backend, sharding, or
//! out-of-core improvement made here immediately covers all three
//! families (biglasso's single C++ path loop, generalized).
//!
//! ## Dynamic (gap-safe) screening
//!
//! Static safe rules fire once per λ and are shut off by the `Flag` once
//! powerless. *Dynamic* rules ([`crate::screening::gapsafe`]) tighten with
//! the current iterate, so the driver treats them differently: the `Flag`
//! shutoff is skipped, and after each inner solve the rule is **re-fired**
//! at the current residual through [`Problem::rescreen`], shrinking the
//! KKT check set. The families additionally re-fire the rule *inside*
//! their inner solves every `rescreen_every` epochs (bounded CD/GD bursts,
//! IRLS rounds for the logistic), pruning the working set mid-optimization
//! — the defining usage of gap-safe sphere rules.
//!
//! ## Fault tolerance (see `docs/ARCHITECTURE.md` § Fault tolerance)
//!
//! Two guardrails harden the walk:
//!
//! * **Graceful degradation** — a *degradable* solver failure at λ_k
//!   ([`HssrError::is_degradable`]: non-convergence or a non-finite
//!   iterate) does not discard the work already done. The driver stops the
//!   walk, truncates the grid to the completed prefix λ_0..λ_{k−1}, and
//!   returns `Ok` with [`DriverFit::error`] carrying a typed [`PathError`]
//!   (index, λ, reason, the partial metrics of the failed λ). Garbage
//!   coefficients are never returned. Non-degradable errors (I/O,
//!   corruption, config) still abort with `Err`.
//! * **Per-λ checkpointing** — with `DriverConfig::checkpoint` set, the
//!   driver serializes the completed λ-prefix (βs, metrics, `Flag`, the
//!   problem's warm-start state via [`Problem::save_state`]) after every λ,
//!   atomically (tmp + rename), sealed with a CRC32. On the next run the
//!   checkpoint resumes the walk at λ_k **bit-identically** to an
//!   uninterrupted fit, provided the configuration matches (rule, pipeline,
//!   dimensions, λ_max, and the completed λ-prefix compared bit-for-bit).

use std::path::Path;
use std::time::Instant;

use crate::data::store::{StoreCounters, StoreSnapshot};
use crate::error::{HssrError, Result};
use crate::obs::trace::{self, Span};
use crate::screening::RuleKind;
use crate::serialize::{crc32, ByteReader, ByteWriter};
use crate::solver::lambda::GridKind;

/// Default for the fused-pipeline switch of every family config
/// (`PathConfig::fused`, `GroupPathConfig::fused`, `LogisticPathConfig::fused`):
/// `true` unless the environment sets `HSSR_FUSED=0`. The knob exists so CI
/// can run the whole test suite through the unfused scan-then-filter
/// drivers as a second configuration; tests that compare the two pipelines
/// pin `fused` explicitly and are unaffected.
pub fn fused_default() -> bool {
    std::env::var("HSSR_FUSED").map(|v| v != "0").unwrap_or(true)
}

/// Default for the fused-epoch flag (`PathConfig::fused_epoch`): `true`
/// unless the environment sets `HSSR_FUSED_EPOCH=0`. When on, a dynamic
/// rule's pre-KKT re-screen republishes the correlations it just scanned
/// into the lazy `z` cache, so the KKT refresh reuses them instead of
/// re-traversing the candidate columns. The residual is unchanged between
/// the two stages, so both settings produce bit-identical paths; the knob
/// exists for the A/B equivalence test and ablation benches.
pub fn fused_epoch_default() -> bool {
    std::env::var("HSSR_FUSED_EPOCH").map(|v| v != "0").unwrap_or(true)
}

/// Per-λ instrumentation (feeds Figures 1/3 and the ablation benches).
/// Shared by every problem family; the group lasso reports *group* counts
/// in the set-size fields.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LambdaMetrics {
    /// λ value.
    pub lambda: f64,
    /// |S| — units surviving safe screening (= p when no safe rule).
    pub safe_size: usize,
    /// |H| — units handed to the optimizer (after violation rounds).
    pub strong_size: usize,
    /// Units KKT-checked after convergence.
    pub kkt_checked: usize,
    /// KKT violations detected (units re-added).
    pub violations: usize,
    /// Inner-solver cycles spent.
    pub cd_cycles: usize,
    /// Individual coordinate updates.
    pub coord_updates: u64,
    /// Columns read by screening/KKT scans at this λ.
    pub cols_scanned: u64,
    /// Nonzero coefficients at the solution.
    pub nonzero: usize,
    /// Objective value at the solution.
    pub objective: f64,
    /// Units discarded by *dynamic* (gap-safe) re-screens after the per-λ
    /// screening stage: mid-solve working-set prunes plus the pre-KKT
    /// [`Problem::rescreen`] hook.
    pub rescreen_discards: usize,
}

/// The problem-independent slice of a path configuration: λ-grid shape and
/// the fused/unfused pipeline switch. Family configs (`PathConfig`,
/// `GroupPathConfig`, `LogisticPathConfig`) lower themselves to this.
#[derive(Clone, Debug)]
pub struct DriverConfig {
    /// Screening strategy (paper "Method").
    pub rule: RuleKind,
    /// Number of λ grid points.
    pub n_lambda: usize,
    /// Smallest λ as a fraction of λmax.
    pub lambda_min_ratio: f64,
    /// Grid spacing.
    pub grid: GridKind,
    /// Explicit λ grid (overrides `n_lambda`/`lambda_min_ratio`).
    pub lambdas: Option<Vec<f64>>,
    /// Drive the fused single-pass screening/KKT pipeline; `false` keeps
    /// the scan-then-filter driver (bit-identical selections, kept for A/B
    /// benchmarking and the equivalence property tests).
    pub fused: bool,
    /// Checkpoint file for crash-resumable paths: after each λ the
    /// completed prefix and the problem's warm-start state are written
    /// here atomically; an existing compatible checkpoint resumes the walk
    /// bit-identically to an uninterrupted fit. `None` disables.
    pub checkpoint: Option<std::path::PathBuf>,
}

/// Outcome of one screening stage ([`Problem::screen`]) at one λ.
#[derive(Clone, Debug, Default)]
pub struct ScreenStage {
    /// The strong / optimizer set `H` (ascending unit indices).
    pub strong: Vec<usize>,
    /// Units discarded by the safe rule in this stage (mask + pointwise).
    pub discarded: usize,
    /// Rule-reported shutoff applicable to the `Flag` logic (masked rules
    /// only; pointwise plans flag purely on the discard count).
    pub rule_dead: bool,
    /// The attached safe rule is *dynamic* (gap-safe): its bound tightens
    /// with the iterate, so the driver must not apply the `Flag` shutoff
    /// on a zero-discard round and re-fires it via [`Problem::rescreen`].
    pub dynamic: bool,
}

/// Typed record of a degradable failure that truncated a λ-path: which λ
/// diverged and why, plus the partial metrics of the failed λ. Carried on
/// [`DriverFit::error`] — the completed prefix is still a valid fit.
#[derive(Clone, Debug, PartialEq)]
pub struct PathError {
    /// Index of the λ at which the solver failed (= the length of the
    /// completed prefix).
    pub lambda_index: usize,
    /// The λ value that failed.
    pub lambda: f64,
    /// Human-readable failure reason (from the typed solver error).
    pub reason: String,
    /// Instrumentation accumulated at the failed λ before the failure.
    pub partial: LambdaMetrics,
}

impl std::fmt::Display for PathError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "path truncated at lambda index {} (lambda = {:.6e}): {}",
            self.lambda_index, self.lambda, self.reason
        )
    }
}

/// Result of a generic path fit. Family-specific wrappers (`PathFit`,
/// `GroupPathFit`, `LogisticPathFit`) are built from this plus whatever
/// extras the problem recorded (e.g. logistic intercepts).
#[derive(Clone, Debug)]
pub struct DriverFit {
    /// The λ grid actually used (decreasing). On a degraded fit this is
    /// the *completed prefix* of the requested grid.
    pub lambdas: Vec<f64>,
    /// Sparse coefficient vectors, one per λ: `(coefficient, value)` pairs.
    pub betas: Vec<Vec<(usize, f64)>>,
    /// Per-λ instrumentation.
    pub metrics: Vec<LambdaMetrics>,
    /// Number of coefficients.
    pub p: usize,
    /// λmax computed from the data.
    pub lambda_max: f64,
    /// Wall-clock seconds for the whole path.
    pub seconds: f64,
    /// Strategy used.
    pub rule: RuleKind,
    /// `Some` when the walk degraded gracefully: the solver failed at
    /// `error.lambda_index` and the fit holds only the completed prefix.
    pub error: Option<PathError>,
}

/// What varies between lasso-type problem families in Algorithm 1. The
/// driver calls these stages in a fixed order per λ; implementations own
/// all numeric state (coefficients, residuals, lazy correlations, safe
/// rules, engines) and must keep fused/unfused selections bit-identical.
pub trait Problem {
    /// Number of screening units: columns for the lasso/logistic, groups
    /// for the group lasso.
    fn n_units(&self) -> usize;

    /// Total coefficient dimension (sparse β extraction runs over this).
    fn n_coef(&self) -> usize;

    /// λmax computed from the data (warm-start grid anchor).
    fn lambda_max(&self) -> f64;

    /// Whether a safe rule is attached. Algorithm 1's `Flag` starts TRUE
    /// (safe screening off) when there is none.
    fn has_safe_rule(&self) -> bool;

    /// Whether post-convergence KKT validation is required. Exact
    /// strategies (Basic) and purely-safe ones (SEDPP) skip it.
    fn needs_kkt(&self) -> bool;

    /// Screening stage at `lam` (Algorithm 1 lines 2–10): run the safe
    /// rule when `run_safe`, lazily refresh stale correlations over the
    /// survivors (line 4), and classify survivors into the strong set
    /// (line 10). Must set `m.safe_size` (survivor count) and account
    /// `m.cols_scanned`. With `fused`, the whole stage runs as one engine
    /// traversal where the family supports it.
    #[allow(clippy::too_many_arguments)]
    fn screen(
        &mut self,
        lam: f64,
        lam_prev: f64,
        run_safe: bool,
        fused: bool,
        survive: &mut [bool],
        m: &mut LambdaMetrics,
    ) -> Result<ScreenStage>;

    /// Inner solve over the strong units (lines 11–13), warm-started from
    /// the current iterate. Must invalidate lazy correlations when the
    /// iterate changed.
    fn solve(
        &mut self,
        lam: f64,
        lambda_index: usize,
        strong: &[usize],
        m: &mut LambdaMetrics,
    ) -> Result<()>;

    /// Dynamic re-screen hook: re-fire a *dynamic* safe rule (gap-safe) at
    /// the **current** residual/dual point — after [`Problem::solve`],
    /// before each KKT pass — clearing `survive` for units that are now
    /// certifiably inactive so the KKT pass skips them. Implementations
    /// must not clear units in `in_strong` (their coefficients live in the
    /// optimizer) **nor units still carrying a nonzero coefficient** (that
    /// would orphan a stale warm-start β past the KKT backstop), and must
    /// leave selections bit-identical between the fused and unfused
    /// pipelines. Returns the number of units discarded.
    ///
    /// Default: no-op — correct for every static rule.
    fn rescreen(
        &mut self,
        _lam: f64,
        _survive: &mut [bool],
        _in_strong: &[bool],
        _m: &mut LambdaMetrics,
    ) -> Result<usize> {
        Ok(0)
    }

    /// Post-convergence KKT pass over `survive \ strong` (lines 14–17):
    /// recompute correlations for the check set and return the violators
    /// (ascending). Must account `m.kkt_checked` / `m.cols_scanned`.
    fn kkt(
        &mut self,
        lam: f64,
        fused: bool,
        survive: &[bool],
        in_strong: &[bool],
        m: &mut LambdaMetrics,
    ) -> Result<Vec<usize>>;

    /// End-of-λ hook (line 18): the unfused pipelines refresh correlations
    /// over the strong set here so the next screening sees the final
    /// residual (the fused pipelines pick them up lazily instead);
    /// families record per-λ extras (e.g. the logistic intercept).
    fn end_lambda(
        &mut self,
        lam: f64,
        fused: bool,
        strong: &[usize],
        m: &mut LambdaMetrics,
    ) -> Result<()>;

    /// Columns the family scanned through its engine *before* the driver
    /// ran (λmax / standardization scans in the constructor, before any
    /// [`LambdaMetrics`] existed). The driver folds this into the first
    /// λ's `cols_scanned` so path accounting matches engine-side traffic
    /// counters exactly. Default: 0 (families that scan nothing in their
    /// constructor).
    fn preamble_cols(&self) -> u64 {
        0
    }

    /// λ-ahead prefetch hook, called after this λ's screening and before
    /// its inner solve: predict the *next* λ's working set from the
    /// current correlations (the SSR threshold is computable before the
    /// solve finishes — the predictive heart of sequential strong rules)
    /// and hand its columns to the engine's async prefetcher. Overlap
    /// only — never correctness. Default: no-op.
    fn prefetch_next(&mut self, _lam: f64, _lam_next: Option<f64>) {}

    /// Sparse nonzero coefficients at the current iterate (ascending).
    fn sparse_beta(&self) -> Vec<(usize, f64)>;

    /// Objective value at the current iterate.
    fn objective(&self, lam: f64) -> f64;

    /// Serialize the family's full warm-path state (coefficients,
    /// residual, lazy-correlation caches, safe-rule state) for a resume
    /// checkpoint. Everything that feeds the next λ must round-trip
    /// bit-for-bit — resumed fits are asserted bit-identical to
    /// uninterrupted ones, *including* scan/metric accounting. `None`
    /// (the default) disables checkpointing for the family.
    fn save_state(&self) -> Option<Vec<u8>> {
        None
    }

    /// Restore state written by [`Problem::save_state`]. The default
    /// rejects — a family that cannot restore must not silently resume
    /// from nothing.
    fn restore_state(&mut self, _state: &[u8]) -> Result<()> {
        Err(HssrError::Config(
            "this problem family does not support checkpoint resume".into(),
        ))
    }

    /// The engine-side I/O counters backing this problem's scans, when
    /// the family computes against a disk-backed store. The tracing layer
    /// snapshots these around each per-λ phase so spans carry chunk/byte
    /// deltas alongside the [`LambdaMetrics`] deltas. Default: `None`
    /// (resident engines do no store I/O).
    fn io_counters(&self) -> Option<&StoreCounters> {
        None
    }
}

/// Captured start-of-phase state for one traced driver stage: the span
/// plus the metric/I-O counter values at entry, so the span's args can be
/// exact deltas. `None` whenever tracing is off — the disabled cost of a
/// stage boundary is one relaxed atomic load.
struct StageTrace {
    span: Span,
    m0: LambdaMetrics,
    io0: Option<StoreSnapshot>,
}

fn stage_begin<P: Problem>(
    prob: &P,
    name: &'static str,
    lam: f64,
    k: usize,
    m: &LambdaMetrics,
) -> Option<StageTrace> {
    if !trace::enabled() {
        return None;
    }
    let mut span = Span::begin(name, "lambda");
    span.arg_f64("lambda", lam);
    span.arg_u64("k", k as u64);
    Some(StageTrace { span, m0: *m, io0: prob.io_counters().map(|c| c.snapshot()) })
}

/// Close a traced stage: attach every counter's movement across the stage
/// (so per-λ span deltas sum exactly to the fit's totals — the invariant
/// `tests/trace_obs.rs` enforces) and emit the span.
fn stage_end<P: Problem>(st: Option<StageTrace>, prob: &P, m: &LambdaMetrics) {
    let Some(mut st) = st else { return };
    let sp = &mut st.span;
    sp.arg_u64("cols_scanned", m.cols_scanned - st.m0.cols_scanned);
    sp.arg_u64("kkt_checked", (m.kkt_checked - st.m0.kkt_checked) as u64);
    sp.arg_u64("violations", (m.violations - st.m0.violations) as u64);
    sp.arg_u64("cd_cycles", (m.cd_cycles - st.m0.cd_cycles) as u64);
    sp.arg_u64("coord_updates", m.coord_updates - st.m0.coord_updates);
    sp.arg_u64(
        "rescreen_discards",
        (m.rescreen_discards - st.m0.rescreen_discards) as u64,
    );
    if let (Some(c), Some(io0)) = (prob.io_counters(), st.io0) {
        let d = c.snapshot().delta_since(&io0);
        sp.arg_u64("cols_fetched", d.cols_fetched);
        sp.arg_u64("chunk_loads", d.chunk_loads);
        sp.arg_u64("bytes_read", d.bytes_read);
        sp.arg_u64("cache_hits", d.cache_hits);
        sp.arg_u64("stalls", d.stalls);
        sp.arg_u64("solver_cols", d.solver_cols);
    }
    // st drops here; the span emits its event.
}

/// Materialize screen-stage discards of still-live units — shared by the
/// three families' `zero_discarded` steps. For every unit with
/// `survive[u] == false`, `evict(u)` zeroes its coefficients back into the
/// residual and reports whether anything actually moved; returns `true`
/// when any unit did (the caller invalidates its lazy correlations).
pub fn zero_discarded_units(
    survive: &[bool],
    mut evict: impl FnMut(usize) -> bool,
) -> bool {
    let mut changed = false;
    for (u, &s) in survive.iter().enumerate() {
        if !s && evict(u) {
            changed = true;
        }
    }
    changed
}

/// Apply a freshly-computed dynamic-rule `mask` to `survive` — the shared
/// tail of every family's [`Problem::rescreen`]: strong units stay (the
/// optimizer owns them), and so does any unit still carrying a warm-start
/// coefficient (`unit_live`) — dropping it would orphan the stale β past
/// the KKT backstop; the KKT pass re-adds such units if needed. Returns
/// the number of units discarded.
pub fn apply_rescreen_mask(
    survive: &mut [bool],
    mask: &[bool],
    in_strong: &[bool],
    mut unit_live: impl FnMut(usize) -> bool,
) -> usize {
    let mut discarded = 0;
    for u in 0..mask.len() {
        if survive[u] && !mask[u] && !in_strong[u] && !unit_live(u) {
            survive[u] = false;
            discarded += 1;
        }
    }
    discarded
}

/// Drop working-set units the dynamic rule no longer keeps, calling
/// `evict` for each pruned unit (the family zeroes its coefficients back
/// into the residual there). Returns the number of units pruned — shared
/// by the families' mid-solve burst prunes.
pub fn prune_working_set(
    work: &mut Vec<usize>,
    keep: &[bool],
    mut evict: impl FnMut(usize),
) -> usize {
    let before = work.len();
    work.retain(|&u| {
        if keep[u] {
            true
        } else {
            evict(u);
            false
        }
    });
    before - work.len()
}

/// The family-specific slice of the shared dynamic burst solve
/// ([`dynamic_burst_solve`]): one optimizer cycle, the gap-safe keep-mask
/// at the current iterate, and coefficient eviction.
pub trait BurstProblem {
    /// Run one optimizer epoch over `work` (a CD or GD cycle), updating
    /// `m.coord_updates`, and return the cycle's max coefficient delta.
    /// Fallible because a store-backed cycle reads from disk; I/O errors
    /// must surface typed (they are *not* degradable divergence).
    fn cycle(&mut self, work: &[usize], m: &mut LambdaMetrics) -> Result<f64>;

    /// Fire the dynamic rule at the *current* iterate, clearing `keep[u]`
    /// for units certified inactive at this λ. Scans must be accounted
    /// into `m.cols_scanned` when engine-routed.
    fn rescreen_keep(&mut self, keep: &mut [bool], m: &mut LambdaMetrics) -> Result<()>;

    /// Zero a pruned unit's coefficients back into the residual.
    fn evict(&mut self, unit: usize);
}

/// The dynamic (gap-safe) inner solve shared by the Gaussian and group
/// families: run the optimizer in bounded bursts of `rescreen_every`
/// epochs, re-firing the rule between bursts at the current residual and
/// pruning the working set — certified-inactive units leave
/// mid-optimization, their coefficients zeroed back into the residual
/// first (safe: the ball certificate is against this λ's optimum).
/// Returns whether any cycle ran (the caller invalidates lazy
/// correlations if so).
#[allow(clippy::too_many_arguments)]
pub fn dynamic_burst_solve<B: BurstProblem>(
    prob: &mut B,
    strong: &[usize],
    n_units: usize,
    rescreen_every: usize,
    max_iter: usize,
    tol: f64,
    lambda_index: usize,
    m: &mut LambdaMetrics,
) -> Result<bool> {
    let mut work: Vec<usize> = strong.to_vec();
    let mut cycles_used = 0usize;
    let mut ran = false;
    while !work.is_empty() {
        let mut converged = false;
        let mut last_delta = f64::INFINITY;
        let burst = rescreen_every.min(max_iter - cycles_used);
        for _ in 0..burst {
            last_delta = prob.cycle(&work, m)?;
            cycles_used += 1;
            m.cd_cycles += 1;
            ran = true;
            if !last_delta.is_finite() {
                // A NaN/Inf delta means the iterate has left the feasible
                // region — converting it to a typed error here is what
                // lets the driver degrade gracefully instead of walking
                // the rest of the path on garbage.
                return Err(HssrError::NonFinite {
                    lambda_index,
                    context: "coefficient update delta".into(),
                });
            }
            if last_delta < tol {
                converged = true;
                break;
            }
        }
        if converged {
            break;
        }
        if cycles_used >= max_iter {
            return Err(HssrError::NoConvergence { lambda_index, max_iter, last_delta });
        }
        let mut keep = vec![true; n_units];
        prob.rescreen_keep(&mut keep, m)?;
        m.rescreen_discards += prune_working_set(&mut work, &keep, |u| prob.evict(u));
    }
    Ok(ran)
}

/// Magic prefix of a driver checkpoint file (version 1).
pub const CHECKPOINT_MAGIC: &[u8; 9] = b"HSSRCKPT1";

/// A completed λ-prefix of a path fit, sufficient to continue (or re-run)
/// the walk from `betas.len()` exactly as an uninterrupted fit would. Two
/// consumers: the per-λ crash-resume checkpoint (serialized to disk with a
/// CRC32 seal) and the serve-mode **warm-start registry**, which keeps
/// finished fits' `WarmStart`s in memory and seeds new requests over the
/// same design from them via [`drive_warm`].
#[derive(Clone, Debug)]
pub struct WarmStart {
    /// `format!("{:?}")` of the rule — adoption refuses a different one.
    pub rule: String,
    /// Fused/unfused pipeline the prefix was fit with.
    pub fused: bool,
    /// Algorithm 1 `Flag` state after the prefix.
    pub flag_off: bool,
    /// Coefficient dimension.
    pub p: usize,
    /// Screening-unit count.
    pub n_units: usize,
    /// λmax of the fit (bit-compared on adoption).
    pub lambda_max: f64,
    /// The last completed λ (warm-start anchor for the next step).
    pub lam_prev: f64,
    /// The completed λ-prefix, bit-compared against the new grid.
    pub lambdas: Vec<f64>,
    /// Sparse coefficients of the completed prefix.
    pub betas: Vec<Vec<(usize, f64)>>,
    /// Per-λ instrumentation of the completed prefix.
    pub metrics: Vec<LambdaMetrics>,
    /// Opaque [`Problem::save_state`] blob.
    pub state: Vec<u8>,
}

impl WarmStart {
    /// Number of λ steps this warm start covers.
    pub fn prefix_len(&self) -> usize {
        self.betas.len()
    }

    /// Whether this prefix can seed a walk with the given shape: same
    /// rule/pipeline/dimensions, bit-identical λmax, and a bit-identical
    /// λ-prefix of the new grid. Callers keying a registry must fold any
    /// remaining solver knobs (tolerance, iteration caps, penalty) into
    /// the key — this check covers only what the driver itself sees.
    pub fn compatible(
        &self,
        rule_label: &str,
        fused: bool,
        p: usize,
        n_units: usize,
        lambda_max: f64,
        lambdas: &[f64],
    ) -> bool {
        self.rule == rule_label
            && self.fused == fused
            && self.p == p
            && self.n_units == n_units
            && self.lambda_max.to_bits() == lambda_max.to_bits()
            && self.lambdas.len() <= lambdas.len()
            && self.lambdas.iter().zip(lambdas).all(|(a, b)| a.to_bits() == b.to_bits())
    }
}

fn encode_metrics(w: &mut ByteWriter, m: &LambdaMetrics) {
    w.put_f64(m.lambda);
    w.put_u64(m.safe_size as u64);
    w.put_u64(m.strong_size as u64);
    w.put_u64(m.kkt_checked as u64);
    w.put_u64(m.violations as u64);
    w.put_u64(m.cd_cycles as u64);
    w.put_u64(m.coord_updates);
    w.put_u64(m.cols_scanned);
    w.put_u64(m.nonzero as u64);
    w.put_f64(m.objective);
    w.put_u64(m.rescreen_discards as u64);
}

fn decode_metrics(r: &mut ByteReader) -> Result<LambdaMetrics> {
    Ok(LambdaMetrics {
        lambda: r.get_f64()?,
        safe_size: r.get_u64()? as usize,
        strong_size: r.get_u64()? as usize,
        kkt_checked: r.get_u64()? as usize,
        violations: r.get_u64()? as usize,
        cd_cycles: r.get_u64()? as usize,
        coord_updates: r.get_u64()?,
        cols_scanned: r.get_u64()?,
        nonzero: r.get_u64()? as usize,
        objective: r.get_f64()?,
        rescreen_discards: r.get_u64()? as usize,
    })
}

/// Serialize and atomically replace the checkpoint file (tmp + rename, so
/// a crash mid-write leaves the previous checkpoint intact), sealed with a
/// trailing CRC32.
fn write_checkpoint(path: &Path, ck: &WarmStart) -> Result<()> {
    let mut w = ByteWriter::new();
    w.put_bytes(CHECKPOINT_MAGIC);
    w.put_blob(ck.rule.as_bytes());
    w.put_u8(ck.fused as u8);
    w.put_u8(ck.flag_off as u8);
    w.put_u64(ck.p as u64);
    w.put_u64(ck.n_units as u64);
    w.put_f64(ck.lambda_max);
    w.put_f64(ck.lam_prev);
    w.put_f64s(&ck.lambdas);
    w.put_u64(ck.betas.len() as u64);
    for b in &ck.betas {
        w.put_u64(b.len() as u64);
        for &(j, v) in b {
            w.put_u64(j as u64);
            w.put_f64(v);
        }
    }
    for m in &ck.metrics {
        encode_metrics(&mut w, m);
    }
    w.put_blob(&ck.state);
    let mut bytes = w.into_bytes();
    let crc = crc32(&bytes);
    bytes.extend_from_slice(&crc.to_le_bytes());
    let tmp = path.with_extension("ckpt-tmp");
    std::fs::write(&tmp, &bytes)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Read and verify a checkpoint file: bad magic, a failed CRC, or any
/// truncation surfaces as a typed [`HssrError::Corrupt`] — a damaged
/// checkpoint must never silently seed a fit.
fn read_checkpoint(path: &Path) -> Result<WarmStart> {
    let bytes = std::fs::read(path)?;
    if bytes.len() < CHECKPOINT_MAGIC.len() + 4 || !bytes.starts_with(CHECKPOINT_MAGIC) {
        return Err(HssrError::Corrupt(format!(
            "{}: not an HSSR checkpoint file",
            path.display()
        )));
    }
    let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
    let mut stored = [0u8; 4];
    stored.copy_from_slice(crc_bytes);
    let stored = u32::from_le_bytes(stored);
    let got = crc32(body);
    if got != stored {
        return Err(HssrError::Corrupt(format!(
            "{}: checkpoint failed CRC32 (stored {stored:#010x}, computed {got:#010x})",
            path.display()
        )));
    }
    let mut r = ByteReader::new(&body[CHECKPOINT_MAGIC.len()..]);
    let rule = String::from_utf8_lossy(r.get_blob()?).into_owned();
    let fused = r.get_u8()? != 0;
    let flag_off = r.get_u8()? != 0;
    let p = r.get_u64()? as usize;
    let n_units = r.get_u64()? as usize;
    let lambda_max = r.get_f64()?;
    let lam_prev = r.get_f64()?;
    let lambdas = r.get_f64s()?;
    let k = r.get_u64()? as usize;
    if k != lambdas.len() {
        return Err(HssrError::Corrupt(format!(
            "{}: checkpoint β count ({k}) disagrees with λ-prefix ({})",
            path.display(),
            lambdas.len()
        )));
    }
    let mut betas = Vec::with_capacity(k);
    for _ in 0..k {
        let nnz = r.get_u64()? as usize;
        if nnz > r.remaining() / 16 {
            return Err(HssrError::Corrupt(format!(
                "{}: checkpoint β block overruns the file",
                path.display()
            )));
        }
        let mut b = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            let j = r.get_u64()? as usize;
            let v = r.get_f64()?;
            b.push((j, v));
        }
        betas.push(b);
    }
    let mut metrics = Vec::with_capacity(k);
    for _ in 0..k {
        metrics.push(decode_metrics(&mut r)?);
    }
    let state = r.get_blob()?.to_vec();
    Ok(WarmStart {
        rule,
        fused,
        flag_off,
        p,
        n_units,
        lambda_max,
        lam_prev,
        lambdas,
        betas,
        metrics,
        state,
    })
}

/// A [`Problem`] paired with its [`DriverConfig`]. The problem owns warm
/// path state (coefficients, residuals, safe-rule shutoff), so a
/// `PathDriver` is **single-use**: construct a fresh problem for each
/// fit. [`drive`] is the underlying free function the `fit_*` shims call
/// directly.
pub struct PathDriver<P: Problem> {
    /// The problem instance (owns all numeric state: coefficients,
    /// residuals, lazy correlations, safe rules, engine handle).
    pub problem: P,
    /// The λ-grid / pipeline configuration.
    pub config: DriverConfig,
}

impl<P: Problem> PathDriver<P> {
    /// Pair a problem with a driver configuration.
    pub fn new(problem: P, config: DriverConfig) -> Self {
        PathDriver { problem, config }
    }

    /// Run Algorithm 1 over the configured λ grid.
    pub fn fit(&mut self) -> Result<DriverFit> {
        drive(&mut self.problem, &self.config)
    }
}

/// Run Algorithm 1 over the λ grid: the single path loop shared by every
/// problem family. See the module docs for the stage contract.
pub fn drive<P: Problem>(prob: &mut P, cfg: &DriverConfig) -> Result<DriverFit> {
    drive_warm(prob, cfg, None).map(|(fit, _)| fit)
}

/// [`drive`] with the serve-mode warm-start hook: when `warm` holds a
/// compatible prefix (see [`WarmStart::compatible`]) the walk adopts it
/// and starts at its end instead of λmax; an incompatible prefix is
/// **silently** ignored (the registry is best-effort — a cold start is
/// always correct). A `--checkpoint` file, when present, takes precedence
/// and keeps its strict error-on-mismatch contract. Returns the fit plus
/// the completed walk's own `WarmStart` (`None` when the family does not
/// support state capture or the path degraded).
pub fn drive_warm<P: Problem>(
    prob: &mut P,
    cfg: &DriverConfig,
    warm: Option<&WarmStart>,
) -> Result<(DriverFit, Option<WarmStart>)> {
    let start = Instant::now();
    let lambda_max = prob.lambda_max();
    let lambdas = match &cfg.lambdas {
        Some(ls) => ls.clone(),
        None => crate::solver::lambda::grid(
            lambda_max,
            cfg.lambda_min_ratio,
            cfg.n_lambda,
            cfg.grid,
        ),
    };
    let units = prob.n_units();
    let needs_kkt = prob.needs_kkt();
    // Algorithm 1 `Flag`: TRUE once the safe rule stops discarding.
    let mut flag_off = !prob.has_safe_rule();
    let mut betas: Vec<Vec<(usize, f64)>> = Vec::with_capacity(lambdas.len());
    let mut metrics: Vec<LambdaMetrics> = Vec::with_capacity(lambdas.len());
    let mut lam_prev = lambda_max;

    // ---- crash-resume: adopt a compatible checkpoint's λ-prefix ----
    let rule_label = format!("{:?}", cfg.rule);

    // Tracing: group everything below (and any spans the problem's engine
    // emits from worker threads it dispatches) under one fit sequence,
    // and wrap the whole walk in a `fit` span carrying the identity args
    // the `hssr trace` summarizer joins on.
    let _fit_scope = trace::FitScope::enter();
    let mut fit_span = Span::begin("fit", "fit");
    if fit_span.is_on() {
        fit_span.arg_str("rule", rule_label.clone());
        fit_span.arg_str("simd", crate::linalg::simd::level().label());
        fit_span.arg_u64("units", units as u64);
        fit_span.arg_u64("n_lambda", lambdas.len() as u64);
        fit_span.arg_u64("fused", cfg.fused as u64);
    }
    if let Some(ck_path) = &cfg.checkpoint {
        if ck_path.exists() {
            let ck = read_checkpoint(ck_path)?;
            let prefix_matches = ck.lambdas.len() <= lambdas.len()
                && ck.lambdas.iter().zip(&lambdas).all(|(a, b)| a.to_bits() == b.to_bits());
            if ck.rule != rule_label
                || ck.fused != cfg.fused
                || ck.p != prob.n_coef()
                || ck.n_units != units
                || ck.lambda_max.to_bits() != lambda_max.to_bits()
                || !prefix_matches
            {
                return Err(HssrError::Config(format!(
                    "{}: checkpoint is from a different fit (rule {}, fused \
                     {}, p {}, units {}, λmax {:.6e}) — delete it or point \
                     --checkpoint elsewhere",
                    ck_path.display(),
                    ck.rule,
                    ck.fused,
                    ck.p,
                    ck.n_units,
                    ck.lambda_max
                )));
            }
            prob.restore_state(&ck.state)?;
            flag_off = ck.flag_off;
            lam_prev = ck.lam_prev;
            betas = ck.betas;
            metrics = ck.metrics;
        }
    }

    // ---- serve-mode warm start: adopt a compatible in-memory prefix ----
    // Only when no checkpoint seeded the walk. Unlike checkpoints, an
    // incompatible registry entry is skipped silently: cold-starting is
    // always correct, and the registry is an opportunistic cache.
    if betas.is_empty() {
        if let Some(w) = warm {
            if !w.betas.is_empty()
                && w.compatible(&rule_label, cfg.fused, prob.n_coef(), units, lambda_max, &lambdas)
                && prob.restore_state(&w.state).is_ok()
            {
                flag_off = w.flag_off;
                lam_prev = w.lam_prev;
                betas = w.betas.clone();
                metrics = w.metrics.clone();
            }
        }
    }

    let mut error = None;
    for (k, &lam) in lambdas.iter().enumerate().skip(betas.len()) {
        let mut m = LambdaMetrics { lambda: lam, ..Default::default() };
        let lam_next = lambdas.get(k + 1).copied();
        match run_one_lambda(
            prob,
            lam,
            lam_prev,
            lam_next,
            k,
            cfg,
            units,
            needs_kkt,
            &mut flag_off,
            &mut m,
        ) {
            Ok(()) => {}
            Err(e) if e.is_degradable() => {
                // Graceful degradation: keep the completed λ-prefix, report
                // the failure as typed data. The current iterate may be
                // garbage — it is *not* harvested.
                error = Some(PathError {
                    lambda_index: k,
                    lambda: lam,
                    reason: e.to_string(),
                    partial: m,
                });
                break;
            }
            Err(e) => return Err(e),
        }
        let sparse = prob.sparse_beta();
        m.nonzero = sparse.len();
        m.objective = prob.objective(lam);
        if !m.objective.is_finite() {
            // Family-independent backstop: whatever slipped past the inner
            // guards, a non-finite objective means this λ's solution is
            // garbage — degrade rather than record it.
            error = Some(PathError {
                lambda_index: k,
                lambda: lam,
                reason: format!("non-finite objective ({})", m.objective),
                partial: m,
            });
            break;
        }
        betas.push(sparse);
        metrics.push(m);
        lam_prev = lam;

        // ---- per-λ checkpoint (atomic tmp + rename) ----
        if let Some(ck_path) = &cfg.checkpoint {
            if let Some(state) = prob.save_state() {
                write_checkpoint(
                    ck_path,
                    &WarmStart {
                        rule: rule_label.clone(),
                        fused: cfg.fused,
                        flag_off,
                        p: prob.n_coef(),
                        n_units: units,
                        lambda_max,
                        lam_prev,
                        lambdas: lambdas[..betas.len()].to_vec(),
                        betas: betas.clone(),
                        metrics: metrics.clone(),
                        state,
                    },
                )?;
            }
        }
    }
    let done = betas.len();
    fit_span.arg_u64("lambdas_done", done as u64);
    drop(fit_span);
    // Capture the completed walk for the warm-start registry. A degraded
    // path is never served as a seed: its final state is suspect.
    let warm_out = if error.is_none() {
        prob.save_state().map(|state| WarmStart {
            rule: rule_label.clone(),
            fused: cfg.fused,
            flag_off,
            p: prob.n_coef(),
            n_units: units,
            lambda_max,
            lam_prev,
            lambdas: lambdas[..done].to_vec(),
            betas: betas.clone(),
            metrics: metrics.clone(),
            state,
        })
    } else {
        None
    };
    let fit = DriverFit {
        lambdas: lambdas[..done].to_vec(),
        betas,
        metrics,
        p: prob.n_coef(),
        lambda_max,
        seconds: start.elapsed().as_secs_f64(),
        rule: cfg.rule,
        error,
    };
    Ok((fit, warm_out))
}

/// One full λ step of Algorithm 1 (screen → solve → dynamic re-screen →
/// KKT → violation rounds → end-of-λ), factored out of [`drive`] so a
/// degradable solver failure can truncate the walk without losing the
/// completed prefix.
#[allow(clippy::too_many_arguments)]
fn run_one_lambda<P: Problem>(
    prob: &mut P,
    lam: f64,
    lam_prev: f64,
    lam_next: Option<f64>,
    k: usize,
    cfg: &DriverConfig,
    units: usize,
    needs_kkt: bool,
    flag_off: &mut bool,
    m: &mut LambdaMetrics,
) -> Result<()> {
    // The `screen` span opens before the preamble fold so the k == 0
    // constructor-scan credit lands inside a span — required for span
    // deltas to sum exactly to the fit's totals.
    let tr = stage_begin(prob, "screen", lam, k, m);
    if k == 0 {
        // Fold the family's constructor-time scans (λmax /
        // standardization checks, issued before any metrics existed) into
        // the first λ so `total_cols_scanned()` equals the engine's
        // `cols_fetched` exactly. Resume-safe: a resumed walk adopts λ0's
        // metrics from the checkpoint and never re-enters k == 0.
        m.cols_scanned += prob.preamble_cols();
    }
    // ---- screening (lines 2–10) ----
    let mut survive = vec![true; units];
    let run_safe = !*flag_off;
    let stage = prob.screen(lam, lam_prev, run_safe, cfg.fused, &mut survive, m)?;
    let dynamic_rule = stage.dynamic;
    if run_safe
        && prob.has_safe_rule()
        && !dynamic_rule
        && (stage.discarded == 0 || stage.rule_dead)
    {
        // |S| = p ⇒ Flag ← TRUE: switch the safe rule off permanently.
        // Dynamic (gap-safe) rules are exempt: their power returns as
        // the solver converges, so they are never shut off.
        *flag_off = true;
        survive.iter_mut().for_each(|s| *s = true);
    }
    stage_end(tr, prob, m);
    let mut strong = stage.strong;
    let mut in_strong = vec![false; units];
    for &u in &strong {
        in_strong[u] = true;
    }

    // ---- λ-ahead prefetch: while this λ's inner solve runs, the async
    // service loads the chunks of λ_{k+1}'s SSR-predicted working set
    // (computable right now — SSR predicts from current correlations).
    {
        let tr = stage_begin(prob, "prefetch", lam, k, m);
        prob.prefetch_next(lam, lam_next);
        stage_end(tr, prob, m);
    }

    // ---- solve + dynamic re-screen + KKT loop (lines 11–18) ----
    loop {
        let tr = stage_begin(prob, "solve", lam, k, m);
        let solved = prob.solve(lam, k, &strong, m);
        stage_end(tr, prob, m);
        solved?;
        if !needs_kkt {
            break; // exact / safe ⇒ nothing to verify
        }
        if dynamic_rule && run_safe {
            // Re-fire the dynamic rule at the converged-on-H residual,
            // where the gap (hence the ball) is at its tightest: units
            // it discards now drop out of the KKT check set entirely.
            let tr = stage_begin(prob, "rescreen", lam, k, m);
            let d = prob.rescreen(lam, &mut survive, &in_strong, m);
            if let Ok(d) = &d {
                m.rescreen_discards += *d;
            }
            stage_end(tr, prob, m);
            d?;
        }
        let tr = stage_begin(prob, "kkt", lam, k, m);
        let viols = prob.kkt(lam, cfg.fused, &survive, &in_strong, m);
        if let Ok(v) = &viols {
            m.violations += v.len();
        }
        stage_end(tr, prob, m);
        let viols = viols?;
        if viols.is_empty() {
            break;
        }
        for &u in &viols {
            in_strong[u] = true;
        }
        strong.extend(viols);
    }

    let tr = stage_begin(prob, "finalize", lam, k, m);
    let ended = prob.end_lambda(lam, cfg.fused, &strong, m);
    stage_end(tr, prob, m);
    ended?;
    m.strong_size = strong.len();
    Ok(())
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    /// A degenerate problem exercising the driver's control flow: one unit,
    /// no safe rule, a "solver" that flips a coefficient on, and a KKT pass
    /// that reports one violation round before going quiet.
    struct Toy {
        beta: f64,
        kkt_rounds: usize,
        solves: usize,
        end_calls: usize,
    }

    impl Problem for Toy {
        fn n_units(&self) -> usize {
            1
        }
        fn n_coef(&self) -> usize {
            1
        }
        fn lambda_max(&self) -> f64 {
            1.0
        }
        fn has_safe_rule(&self) -> bool {
            false
        }
        fn needs_kkt(&self) -> bool {
            true
        }
        fn screen(
            &mut self,
            _lam: f64,
            _lam_prev: f64,
            _run_safe: bool,
            _fused: bool,
            survive: &mut [bool],
            m: &mut LambdaMetrics,
        ) -> Result<ScreenStage> {
            m.safe_size = survive.len();
            Ok(ScreenStage::default())
        }
        fn solve(
            &mut self,
            _lam: f64,
            _k: usize,
            strong: &[usize],
            _m: &mut LambdaMetrics,
        ) -> Result<()> {
            self.solves += 1;
            if !strong.is_empty() {
                self.beta = 0.5;
            }
            Ok(())
        }
        fn kkt(
            &mut self,
            _lam: f64,
            _fused: bool,
            _survive: &[bool],
            in_strong: &[bool],
            m: &mut LambdaMetrics,
        ) -> Result<Vec<usize>> {
            if !in_strong[0] && self.kkt_rounds == 0 {
                self.kkt_rounds += 1;
                m.kkt_checked += 1;
                return Ok(vec![0]);
            }
            Ok(Vec::new())
        }
        fn end_lambda(
            &mut self,
            _lam: f64,
            _fused: bool,
            _strong: &[usize],
            _m: &mut LambdaMetrics,
        ) -> Result<()> {
            self.end_calls += 1;
            Ok(())
        }
        fn sparse_beta(&self) -> Vec<(usize, f64)> {
            if self.beta != 0.0 {
                vec![(0, self.beta)]
            } else {
                Vec::new()
            }
        }
        fn objective(&self, _lam: f64) -> f64 {
            0.0
        }
    }

    #[test]
    fn violation_rounds_readd_units_and_loop() {
        let mut prob = Toy { beta: 0.0, kkt_rounds: 0, solves: 0, end_calls: 0 };
        let cfg = DriverConfig {
            rule: RuleKind::Ssr,
            n_lambda: 2,
            lambda_min_ratio: 0.5,
            grid: GridKind::Linear,
            lambdas: None,
            fused: true,
            checkpoint: None,
        };
        let fit = drive(&mut prob, &cfg).unwrap();
        assert_eq!(fit.lambdas.len(), 2);
        // first λ: empty strong → KKT violation → re-solve with unit 0.
        assert_eq!(fit.metrics[0].violations, 1);
        assert_eq!(fit.metrics[0].strong_size, 1);
        assert_eq!(fit.betas[0], vec![(0, 0.5)]);
        // the driver called solve twice at λ#0 (violation round) and once
        // more at λ#1, and end_lambda exactly once per λ.
        assert_eq!(prob.solves, 3);
        assert_eq!(prob.end_calls, 2);
        assert_eq!(fit.p, 1);
    }

    #[test]
    fn explicit_grid_respected() {
        let mut prob = Toy { beta: 0.0, kkt_rounds: 1, solves: 0, end_calls: 0 };
        let cfg = DriverConfig {
            rule: RuleKind::BasicPcd,
            n_lambda: 100,
            lambda_min_ratio: 0.1,
            grid: GridKind::Linear,
            lambdas: Some(vec![0.7, 0.2]),
            fused: false,
            checkpoint: None,
        };
        let fit = drive(&mut prob, &cfg).unwrap();
        assert_eq!(fit.lambdas, vec![0.7, 0.2]);
        assert_eq!(fit.rule, RuleKind::BasicPcd);
        assert!(fit.error.is_none());
    }

    /// A problem whose solver diverges at a chosen λ index: the driver must
    /// return the completed prefix with a typed [`PathError`], never `Err`
    /// and never garbage coefficients at the failed λ.
    struct Diverging {
        fail_at: usize,
    }

    impl Problem for Diverging {
        fn n_units(&self) -> usize {
            1
        }
        fn n_coef(&self) -> usize {
            1
        }
        fn lambda_max(&self) -> f64 {
            1.0
        }
        fn has_safe_rule(&self) -> bool {
            false
        }
        fn needs_kkt(&self) -> bool {
            false
        }
        fn screen(
            &mut self,
            _lam: f64,
            _lam_prev: f64,
            _run_safe: bool,
            _fused: bool,
            _survive: &mut [bool],
            m: &mut LambdaMetrics,
        ) -> Result<ScreenStage> {
            m.safe_size = 1;
            Ok(ScreenStage { strong: vec![0], ..Default::default() })
        }
        fn solve(
            &mut self,
            _lam: f64,
            lambda_index: usize,
            _strong: &[usize],
            _m: &mut LambdaMetrics,
        ) -> Result<()> {
            if lambda_index == self.fail_at {
                return Err(HssrError::NonFinite {
                    lambda_index,
                    context: "residual".into(),
                });
            }
            Ok(())
        }
        fn kkt(
            &mut self,
            _lam: f64,
            _fused: bool,
            _survive: &[bool],
            _in_strong: &[bool],
            _m: &mut LambdaMetrics,
        ) -> Result<Vec<usize>> {
            Ok(Vec::new())
        }
        fn end_lambda(
            &mut self,
            _lam: f64,
            _fused: bool,
            _strong: &[usize],
            _m: &mut LambdaMetrics,
        ) -> Result<()> {
            Ok(())
        }
        fn sparse_beta(&self) -> Vec<(usize, f64)> {
            vec![(0, 0.25)]
        }
        fn objective(&self, _lam: f64) -> f64 {
            0.0
        }
    }

    #[test]
    fn degradable_failure_truncates_to_completed_prefix() {
        let mut prob = Diverging { fail_at: 2 };
        let cfg = DriverConfig {
            rule: RuleKind::BasicPcd,
            n_lambda: 5,
            lambda_min_ratio: 0.1,
            grid: GridKind::Linear,
            lambdas: None,
            fused: true,
            checkpoint: None,
        };
        let fit = drive(&mut prob, &cfg).unwrap();
        assert_eq!(fit.lambdas.len(), 2, "prefix before the failed λ only");
        assert_eq!(fit.betas.len(), 2);
        assert_eq!(fit.metrics.len(), 2);
        let err = fit.error.expect("degradation must be reported");
        assert_eq!(err.lambda_index, 2);
        assert!(err.reason.contains("non-finite"), "got {}", err.reason);
        // A failure at λ#0 yields an empty-but-Ok fit.
        let mut prob = Diverging { fail_at: 0 };
        let fit = drive(&mut prob, &cfg).unwrap();
        assert!(fit.lambdas.is_empty() && fit.betas.is_empty());
        assert_eq!(fit.error.unwrap().lambda_index, 0);
    }

    #[test]
    fn checkpoint_roundtrip_and_corruption_detection() {
        let dir = std::env::temp_dir().join("hssr_driver_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.ckpt");
        let ck = WarmStart {
            rule: "SsrBedpp".into(),
            fused: true,
            flag_off: false,
            p: 11,
            n_units: 11,
            lambda_max: 0.75,
            lam_prev: 0.3,
            lambdas: vec![0.75, 0.5, 0.3],
            betas: vec![vec![], vec![(3, -0.5)], vec![(3, -0.75), (7, 0.125)]],
            metrics: vec![
                LambdaMetrics { lambda: 0.75, ..Default::default() },
                LambdaMetrics { lambda: 0.5, cd_cycles: 4, ..Default::default() },
                LambdaMetrics { lambda: 0.3, cols_scanned: 9, ..Default::default() },
            ],
            state: vec![1, 2, 3, 250],
        };
        write_checkpoint(&path, &ck).unwrap();
        let back = read_checkpoint(&path).unwrap();
        assert_eq!(back.rule, ck.rule);
        assert_eq!((back.fused, back.flag_off), (true, false));
        assert_eq!((back.p, back.n_units), (11, 11));
        assert_eq!(back.lambda_max.to_bits(), ck.lambda_max.to_bits());
        assert_eq!(back.lam_prev.to_bits(), ck.lam_prev.to_bits());
        assert_eq!(back.lambdas, ck.lambdas);
        assert_eq!(back.betas, ck.betas);
        assert_eq!(back.metrics, ck.metrics);
        assert_eq!(back.state, ck.state);
        // a flipped byte in the body fails the trailing CRC, typed
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x04;
        let bad = dir.join("corrupt.ckpt");
        std::fs::write(&bad, &bytes).unwrap();
        assert!(matches!(read_checkpoint(&bad), Err(HssrError::Corrupt(_))));
        // garbage file: typed, not a panic
        std::fs::write(&bad, b"not a checkpoint").unwrap();
        assert!(matches!(read_checkpoint(&bad), Err(HssrError::Corrupt(_))));
    }

    /// A stateful toy family: `value` increments once per solve and is the
    /// reported coefficient, so a warm-started walk is distinguishable
    /// from a cold one by counting `solve_calls`.
    struct Resumable {
        solve_calls: usize,
        value: f64,
    }

    impl Problem for Resumable {
        fn n_units(&self) -> usize {
            1
        }
        fn n_coef(&self) -> usize {
            1
        }
        fn lambda_max(&self) -> f64 {
            1.0
        }
        fn has_safe_rule(&self) -> bool {
            false
        }
        fn needs_kkt(&self) -> bool {
            false
        }
        fn screen(
            &mut self,
            _lam: f64,
            _lam_prev: f64,
            _run_safe: bool,
            _fused: bool,
            _survive: &mut [bool],
            _m: &mut LambdaMetrics,
        ) -> Result<ScreenStage> {
            Ok(ScreenStage { strong: vec![0], ..Default::default() })
        }
        fn solve(
            &mut self,
            _lam: f64,
            _lambda_index: usize,
            _strong: &[usize],
            _m: &mut LambdaMetrics,
        ) -> Result<()> {
            self.solve_calls += 1;
            self.value += 1.0;
            Ok(())
        }
        fn kkt(
            &mut self,
            _lam: f64,
            _fused: bool,
            _survive: &[bool],
            _in_strong: &[bool],
            _m: &mut LambdaMetrics,
        ) -> Result<Vec<usize>> {
            Ok(Vec::new())
        }
        fn end_lambda(
            &mut self,
            _lam: f64,
            _fused: bool,
            _strong: &[usize],
            _m: &mut LambdaMetrics,
        ) -> Result<()> {
            Ok(())
        }
        fn sparse_beta(&self) -> Vec<(usize, f64)> {
            vec![(0, self.value)]
        }
        fn objective(&self, _lam: f64) -> f64 {
            0.0
        }
        fn save_state(&self) -> Option<Vec<u8>> {
            Some(self.value.to_le_bytes().to_vec())
        }
        fn restore_state(&mut self, state: &[u8]) -> Result<()> {
            let mut b = [0u8; 8];
            if state.len() != 8 {
                return Err(HssrError::Corrupt("bad Resumable state".into()));
            }
            b.copy_from_slice(state);
            self.value = f64::from_le_bytes(b);
            Ok(())
        }
    }

    #[test]
    fn warm_start_adopts_compatible_prefix_and_skips_it() {
        let cfg2 = DriverConfig {
            rule: RuleKind::BasicPcd,
            n_lambda: 2,
            lambda_min_ratio: 0.5,
            grid: GridKind::Linear,
            lambdas: Some(vec![0.8, 0.4]),
            fused: false,
            checkpoint: None,
        };
        let mut prob = Resumable { solve_calls: 0, value: 0.0 };
        let (fit, warm) = drive_warm(&mut prob, &cfg2, None).unwrap();
        assert_eq!(fit.lambdas.len(), 2);
        assert_eq!(prob.solve_calls, 2);
        let warm = warm.expect("stateful family must emit a warm start");
        assert_eq!(warm.prefix_len(), 2);

        // Extended grid sharing the prefix: only the new λ is solved, and
        // the adopted prefix is returned verbatim.
        let cfg3 = DriverConfig {
            rule: RuleKind::BasicPcd,
            n_lambda: 3,
            lambda_min_ratio: 0.5,
            grid: GridKind::Linear,
            lambdas: Some(vec![0.8, 0.4, 0.2]),
            fused: false,
            checkpoint: None,
        };
        let mut seeded = Resumable { solve_calls: 0, value: 0.0 };
        let (fit3, warm3) = drive_warm(&mut seeded, &cfg3, Some(&warm)).unwrap();
        assert_eq!(seeded.solve_calls, 1, "warm start must skip the shared prefix");
        assert_eq!(fit3.lambdas.len(), 3);
        assert_eq!(fit3.betas[..2], fit.betas[..2]);
        assert_eq!(fit3.betas[2], vec![(0, 3.0)], "state must carry across the seam");
        assert_eq!(warm3.expect("completed walk").prefix_len(), 3);

        // An incompatible entry (different pipeline flag) is skipped
        // silently: full cold start, no error.
        let cfg_bad = DriverConfig {
            rule: RuleKind::BasicPcd,
            n_lambda: 3,
            lambda_min_ratio: 0.5,
            grid: GridKind::Linear,
            lambdas: Some(vec![0.8, 0.4, 0.2]),
            fused: true,
            checkpoint: None,
        };
        let mut cold = Resumable { solve_calls: 0, value: 0.0 };
        let (fit_cold, _) = drive_warm(&mut cold, &cfg_bad, Some(&warm)).unwrap();
        assert_eq!(cold.solve_calls, 3, "incompatible warm start must cold-start");
        assert_eq!(fit_cold.lambdas.len(), 3);
    }
}
