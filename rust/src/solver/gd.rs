//! Blockwise group-descent inner loop for the group lasso and group
//! elastic net (Qin et al. 2013; Breheny & Huang 2015; Meier et al. 2008).
//!
//! Under the group orthonormalization (19) each block update is closed form
//! (the multivariate soft threshold, with the elastic-net proximal scaling
//! exactly mirroring the column CD update):
//!
//! ```text
//! z_g   = X_gᵀr/n + β_g
//! β_g⁺  = (1 − αλ√W_g / ‖z_g‖)₊ · z_g / (1 + (1−α)λ)     (lasso: α = 1)
//! r    −= X_g (β_g⁺ − β_g)
//! ```

use crate::error::{HssrError, Result};
use crate::linalg::{ops, DenseMatrix};
use super::cd::CdStats;
use super::columns::{ColAccess, DenseCols};
use super::Penalty;

/// One full cycle of group updates over `active` (group indices), served
/// by any column source. Each group makes two passes over its columns
/// (norm accumulation, then the update axpys); for a group straddling a
/// chunk boundary, the second pass is a *backward* move for a pinned
/// store cursor — just another pin swap. Returns the largest |Δβ_j|
/// across all coordinates; `Err` only from a store-backed source.
#[allow(clippy::too_many_arguments)]
pub fn gd_cycle_on<C: ColAccess>(
    cols: &mut C,
    penalty: Penalty,
    lam: f64,
    active: &[usize],
    starts: &[usize],
    sizes: &[usize],
    beta: &mut [f64],
    r: &mut [f64],
) -> Result<f64> {
    let n_inv = 1.0 / cols.nrows() as f64;
    let alpha = penalty.alpha();
    let denom = 1.0 + penalty.l2_weight() * lam;
    let mut max_delta = 0.0f64;
    let mut z = Vec::new();
    for &g in active {
        let (j0, w) = (starts[g], sizes[g]);
        z.clear();
        z.reserve(w);
        let mut z_norm_sq = 0.0;
        for dj in 0..w {
            let zj = ops::dot(cols.col(j0 + dj)?, r) * n_inv + beta[j0 + dj];
            z_norm_sq += zj * zj;
            z.push(zj);
        }
        let z_norm = z_norm_sq.sqrt();
        let thresh = alpha * lam * (w as f64).sqrt();
        let scale =
            if z_norm > thresh { (1.0 - thresh / z_norm) / denom } else { 0.0 };
        for dj in 0..w {
            let b_new = scale * z[dj];
            let delta = b_new - beta[j0 + dj];
            if delta != 0.0 {
                ops::axpy(-delta, cols.col(j0 + dj)?, r);
                beta[j0 + dj] = b_new;
                max_delta = max_delta.max(delta.abs());
            }
        }
    }
    Ok(max_delta)
}

/// One full cycle of group updates over `active` (group indices) on the
/// resident design. Returns the largest |Δβ_j| across all coordinates.
#[allow(clippy::too_many_arguments)]
pub fn gd_cycle(
    x: &DenseMatrix,
    penalty: Penalty,
    lam: f64,
    active: &[usize],
    starts: &[usize],
    sizes: &[usize],
    beta: &mut [f64],
    r: &mut [f64],
) -> f64 {
    // The dense source never errs.
    gd_cycle_on(&mut DenseCols::new(x), penalty, lam, active, starts, sizes, beta, r)
        .unwrap_or(f64::NAN)
}

/// Iterate [`gd_cycle_on`] to convergence.
#[allow(clippy::too_many_arguments)]
pub fn gd_solve_on<C: ColAccess>(
    cols: &mut C,
    penalty: Penalty,
    lam: f64,
    active: &[usize],
    starts: &[usize],
    sizes: &[usize],
    beta: &mut [f64],
    r: &mut [f64],
    tol: f64,
    max_iter: usize,
    lambda_index: usize,
) -> Result<CdStats> {
    let mut stats = CdStats::default();
    if active.is_empty() {
        return Ok(stats);
    }
    let mut last_delta = f64::INFINITY;
    for _ in 0..max_iter {
        last_delta = gd_cycle_on(cols, penalty, lam, active, starts, sizes, beta, r)?;
        stats.cycles += 1;
        stats.coord_updates += active.iter().map(|&g| sizes[g] as u64).sum::<u64>();
        if !last_delta.is_finite() {
            // Divergence guardrail — see `cd_solve`.
            return Err(HssrError::NonFinite {
                lambda_index,
                context: "group-descent update delta".into(),
            });
        }
        if last_delta < tol {
            // NaN block correlations scale to 0 (the `z_norm > thresh`
            // comparison is false for NaN), so verify the residual before
            // trusting an apparently-converged iterate.
            if r.iter().any(|v| !v.is_finite()) {
                return Err(HssrError::NonFinite {
                    lambda_index,
                    context: "group-descent residual".into(),
                });
            }
            return Ok(stats);
        }
    }
    Err(HssrError::NoConvergence { lambda_index, max_iter, last_delta })
}

/// [`gd_solve_on`] over the resident design — the historical entry point.
#[allow(clippy::too_many_arguments)]
pub fn gd_solve(
    x: &DenseMatrix,
    penalty: Penalty,
    lam: f64,
    active: &[usize],
    starts: &[usize],
    sizes: &[usize],
    beta: &mut [f64],
    r: &mut [f64],
    tol: f64,
    max_iter: usize,
    lambda_index: usize,
) -> Result<CdStats> {
    gd_solve_on(
        &mut DenseCols::new(x),
        penalty,
        lam,
        active,
        starts,
        sizes,
        beta,
        r,
        tol,
        max_iter,
        lambda_index,
    )
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::data::synth::generate_grouped;
    use crate::linalg::blocked;

    /// A poisoned residual must surface as a typed `NonFinite` error, not
    /// a silently "converged" garbage iterate.
    #[test]
    fn divergence_is_typed_nonfinite() {
        let ds = generate_grouped(30, 4, 3, 2, 7);
        let active: Vec<usize> = (0..4).collect();
        let mut beta = vec![0.0; ds.p()];
        let mut r = ds.y.clone();
        r[5] = f64::INFINITY;
        let err = gd_solve(
            &ds.x,
            Penalty::Lasso,
            1e-3,
            &active,
            &ds.layout.starts,
            &ds.layout.sizes,
            &mut beta,
            &mut r,
            1e-9,
            50,
            3,
        )
        .unwrap_err();
        assert!(
            matches!(err, HssrError::NonFinite { lambda_index: 3, .. })
                || matches!(err, HssrError::NoConvergence { .. }),
            "wrong error {err}"
        );
    }

    /// With orthonormal groups and a *single* group active, the solution is
    /// the closed-form multivariate soft threshold of X_gᵀy/n.
    #[test]
    fn single_group_closed_form() {
        let ds = generate_grouped(50, 1, 4, 1, 1);
        let w = ds.layout.sizes[0];
        let lam = 0.2;
        let mut beta = vec![0.0; ds.p()];
        let mut r = ds.y.clone();
        gd_solve(
            &ds.x,
            Penalty::Lasso,
            lam,
            &[0],
            &ds.layout.starts,
            &ds.layout.sizes,
            &mut beta,
            &mut r,
            1e-12,
            200,
            0,
        )
        .unwrap();
        let z = blocked::scan_all_vec(&ds.x, &ds.y);
        let z_norm = ops::nrm2(&z[..w]);
        let thresh = lam * (w as f64).sqrt();
        let scale = if z_norm > thresh { 1.0 - thresh / z_norm } else { 0.0 };
        for j in 0..w {
            assert!((beta[j] - scale * z[j]).abs() < 1e-9, "β[{j}]");
        }
    }

    /// With orthonormal groups, the elastic-net solution for a single
    /// active group is the multivariate soft threshold by αλ√W scaled by
    /// 1/(1 + (1−α)λ).
    #[test]
    fn single_group_enet_closed_form() {
        let ds = generate_grouped(50, 1, 4, 1, 12);
        let w = ds.layout.sizes[0];
        let lam = 0.2;
        let alpha = 0.6;
        let pen = Penalty::ElasticNet { alpha };
        let mut beta = vec![0.0; ds.p()];
        let mut r = ds.y.clone();
        gd_solve(
            &ds.x,
            pen,
            lam,
            &[0],
            &ds.layout.starts,
            &ds.layout.sizes,
            &mut beta,
            &mut r,
            1e-12,
            200,
            0,
        )
        .unwrap();
        let z = blocked::scan_all_vec(&ds.x, &ds.y);
        let z_norm = ops::nrm2(&z[..w]);
        let thresh = alpha * lam * (w as f64).sqrt();
        let denom = 1.0 + (1.0 - alpha) * lam;
        let scale =
            if z_norm > thresh { (1.0 - thresh / z_norm) / denom } else { 0.0 };
        for j in 0..w {
            assert!((beta[j] - scale * z[j]).abs() < 1e-9, "enet β[{j}]");
        }
    }

    /// Group KKT at the solution: active groups satisfy
    /// X_gᵀr/n = λ√W_g·β_g/‖β_g‖; inactive groups ‖X_gᵀr/n‖ ≤ λ√W_g.
    #[test]
    fn group_kkt_satisfied() {
        let ds = generate_grouped(80, 8, 3, 3, 2);
        let lam = 0.15;
        let active: Vec<usize> = (0..8).collect();
        let mut beta = vec![0.0; ds.p()];
        let mut r = ds.y.clone();
        gd_solve(
            &ds.x,
            Penalty::Lasso,
            lam,
            &active,
            &ds.layout.starts,
            &ds.layout.sizes,
            &mut beta,
            &mut r,
            1e-11,
            20_000,
            0,
        )
        .unwrap();
        for g in 0..8 {
            let rg = ds.layout.range(g);
            let zg: Vec<f64> = rg
                .clone()
                .map(|j| ops::dot(ds.x.col(j), &r) / 80.0)
                .collect();
            let bg: Vec<f64> = rg.clone().map(|j| beta[j]).collect();
            let bnorm = ops::nrm2(&bg);
            let w_sqrt = (ds.layout.sizes[g] as f64).sqrt();
            if bnorm > 0.0 {
                for (k, j) in rg.enumerate() {
                    let want = lam * w_sqrt * beta[j] / bnorm;
                    assert!((zg[k] - want).abs() < 1e-6, "active group {g} col {k}");
                }
            } else {
                assert!(ops::nrm2(&zg) <= lam * w_sqrt + 1e-6, "inactive group {g}");
            }
        }
    }

    #[test]
    fn residual_consistency() {
        let ds = generate_grouped(40, 5, 3, 2, 3);
        let active: Vec<usize> = (0..5).collect();
        let mut beta = vec![0.0; ds.p()];
        let mut r = ds.y.clone();
        gd_solve(
            &ds.x,
            Penalty::Lasso,
            0.1,
            &active,
            &ds.layout.starts,
            &ds.layout.sizes,
            &mut beta,
            &mut r,
            1e-10,
            20_000,
            0,
        )
        .unwrap();
        let fit = ds.x.matvec(&beta);
        for i in 0..40 {
            assert!((r[i] - (ds.y[i] - fit[i])).abs() < 1e-8);
        }
    }

    #[test]
    fn zero_solution_at_lambda_max() {
        let ds = generate_grouped(60, 6, 4, 2, 4);
        let ctx = crate::screening::group::GroupSafeContext::build(
            &ds.x,
            &ds.y,
            &ds.layout,
            Penalty::Lasso,
        );
        let active: Vec<usize> = (0..6).collect();
        let mut beta = vec![0.0; ds.p()];
        let mut r = ds.y.clone();
        gd_solve(
            &ds.x,
            Penalty::Lasso,
            ctx.lambda_max * 1.0001,
            &active,
            &ds.layout.starts,
            &ds.layout.sizes,
            &mut beta,
            &mut r,
            1e-10,
            1000,
            0,
        )
        .unwrap();
        assert!(beta.iter().all(|&b| b == 0.0));
    }
}
