//! Sparse logistic regression — the paper's §6 future-work extension
//! ("we are currently working on extending the hybrid screening idea to
//! other lasso-type problems such as sparse logistic regression").
//!
//! The ℓ1-penalized logistic model is
//!
//! ```text
//! min_{b,β}  (1/n) Σᵢ [ log(1 + e^{ηᵢ}) − yᵢηᵢ ]  +  λα‖β‖₁ + λ(1−α)/2‖β‖²,
//! ηᵢ = b + xᵢᵀβ,   yᵢ ∈ {0,1},
//! ```
//!
//! solved by IRLS-wrapped coordinate descent (glmnet/biglasso style): each
//! outer iteration builds the weighted least-squares surrogate at the
//! current `(b, β)` and runs penalized weighted CD to convergence.
//!
//! The λ-loop lives in the **generic driver**
//! ([`crate::solver::driver::drive`]) — the same Algorithm-1 skeleton as
//! the Gaussian families; this module contributes [`LogisticProblem`]:
//! the IRLS inner optimizer, the score residual `y − p̂` as the working
//! response for screening, and lazy `score_j = x_jᵀ(y − p̂)/n`
//! bookkeeping. All screening and KKT scans dispatch through
//! [`ScanEngine`] on the shared persistent worker pool — fused
//! single-traversal passes by default ([`LogisticPathConfig::fused`]),
//! scan-then-filter otherwise, with bit-identical selections.
//!
//! The *sequential strong rule* carries over directly (Tibshirani et al.
//! 2012 §7): discard `j` at `λ_{k+1}` if `|x_jᵀ(y − p̂(λ_k))/n| <
//! α(2λ_{k+1} − λ_k)`, with post-convergence KKT checking against
//! `|x_jᵀ(y − p̂)/n| ≤ αλ`. The *static* quadratic-loss safe rules
//! (BEDPP/Dome/SEDPP) do **not** port — their dual geometry is specific to
//! the squared loss — but the **dynamic gap-safe sphere rule does**
//! ([`crate::screening::gapsafe`]): the logistic dual is strongly concave,
//! so a duality-gap ball around the scaled score residual certifies
//! inactive features at any iterate. `RuleKind::SsrGapSafe` therefore
//! makes this the repo's first safe-screened GLM: supported strategies are
//! Basic, AC, SSR, and SSR-GapSafe.

use crate::data::Dataset;
use crate::error::{HssrError, Result};
use crate::linalg::{ops, DenseMatrix};
use crate::runtime::{native::NativeEngine, ooc, ScanEngine};
use crate::screening::{gapsafe, ssr, PrevSolution, RuleKind, SafeContext, SafeRule};
use crate::serialize::{ByteReader, ByteWriter};
use crate::solver::columns::{self, ColAccess, ColSource};
use crate::solver::driver::{
    apply_rescreen_mask, drive, prune_working_set, zero_discarded_units, DriverConfig,
    PathError, Problem, ScreenStage,
};
use crate::solver::lambda::GridKind;
use crate::solver::path::{column_kkt, column_refresh, LambdaMetrics};
use crate::solver::Penalty;

/// Configuration for the logistic path.
#[derive(Clone, Debug)]
pub struct LogisticPathConfig {
    /// Strategy: `BasicPcd`, `ActiveCycling`, or `Ssr`.
    pub rule: RuleKind,
    /// Penalty (α mixing).
    pub penalty: Penalty,
    /// Grid points.
    pub n_lambda: usize,
    /// λmin/λmax ratio.
    pub lambda_min_ratio: f64,
    /// Grid spacing.
    pub grid: GridKind,
    /// CD convergence tolerance.
    pub tol: f64,
    /// Max outer IRLS iterations per λ.
    pub max_irls: usize,
    /// Max CD cycles per IRLS step.
    pub max_iter: usize,
    /// Drive the fused single-pass screening/KKT pipeline (default); the
    /// unfused scan-then-filter driver selects identical feature sets.
    pub fused: bool,
    /// Re-fire a *dynamic* gap-safe rule between IRLS rounds (the logistic
    /// family's inner "epochs"), pruning the working set mid-optimization;
    /// `0` disables the mid-solve prunes. Ignored by static strategies.
    pub rescreen_every: usize,
    /// Explicit λ grid (overrides `n_lambda`/`lambda_min_ratio`).
    pub lambdas: Option<Vec<f64>>,
    /// Write a crash-resumable checkpoint here after every completed λ and
    /// resume from it when it already exists (see the generic driver).
    pub checkpoint: Option<std::path::PathBuf>,
}

impl Default for LogisticPathConfig {
    fn default() -> Self {
        LogisticPathConfig {
            rule: RuleKind::Ssr,
            penalty: Penalty::Lasso,
            n_lambda: 100,
            lambda_min_ratio: 0.05,
            grid: GridKind::Log,
            tol: 1e-7,
            max_irls: 50,
            max_iter: 10_000,
            fused: crate::solver::driver::fused_default(),
            rescreen_every: 1,
            lambdas: None,
            checkpoint: None,
        }
    }
}

impl LogisticPathConfig {
    /// Lower to the problem-independent driver configuration.
    fn driver(&self) -> DriverConfig {
        DriverConfig {
            rule: self.rule,
            n_lambda: self.n_lambda,
            lambda_min_ratio: self.lambda_min_ratio,
            grid: self.grid,
            lambdas: self.lambdas.clone(),
            fused: self.fused,
            checkpoint: self.checkpoint.clone(),
        }
    }
}

/// Result of a logistic path fit.
#[derive(Clone, Debug)]
pub struct LogisticPathFit {
    /// λ grid.
    pub lambdas: Vec<f64>,
    /// Intercept per λ.
    pub intercepts: Vec<f64>,
    /// Sparse coefficients per λ.
    pub betas: Vec<Vec<(usize, f64)>>,
    /// Per-λ instrumentation.
    pub metrics: Vec<LambdaMetrics>,
    /// Features.
    pub p: usize,
    /// λmax.
    pub lambda_max: f64,
    /// Wall-clock seconds.
    pub seconds: f64,
    /// Strategy.
    pub rule: RuleKind,
    /// When the path degraded gracefully, the λ step it stopped at and
    /// why; the per-λ vectors above hold the completed prefix.
    pub error: Option<PathError>,
}

impl LogisticPathFit {
    /// Dense coefficients at grid index `k`.
    pub fn beta_dense(&self, k: usize) -> Vec<f64> {
        let mut b = vec![0.0; self.p];
        for &(j, v) in &self.betas[k] {
            b[j] = v;
        }
        b
    }

    /// Total columns scanned over the path (screening + KKT, plus the
    /// constructor's λmax/standardization preamble folded into λ0).
    pub fn total_cols_scanned(&self) -> u64 {
        self.metrics.iter().map(|m| m.cols_scanned).sum()
    }

    /// Predicted probabilities on the (standardized) design at index `k`.
    pub fn predict_proba(&self, x: &DenseMatrix, k: usize) -> Vec<f64> {
        let beta = self.beta_dense(k);
        let mut eta = x.matvec(&beta);
        for e in eta.iter_mut() {
            *e = sigmoid(*e + self.intercepts[k]);
        }
        eta
    }
}

#[inline]
fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Binomial deviance (−2·loglik/n) of probabilities `p` against labels `y`.
pub fn deviance(y: &[f64], p: &[f64]) -> f64 {
    let eps = 1e-12;
    let mut d = 0.0;
    for (yi, pi) in y.iter().zip(p) {
        let pi = pi.clamp(eps, 1.0 - eps);
        d -= 2.0 * (yi * pi.ln() + (1.0 - yi) * (1.0 - pi).ln());
    }
    d / y.len() as f64
}

/// One weighted CD cycle on the IRLS surrogate, served by any column
/// source (resident design or pinned store cursor — `active` ascending, so
/// the cursor swaps each chunk at most once per cycle). `w` are the IRLS
/// weights, `r` is the working residual `z − η` (maintained exactly),
/// `xwx[j] = Σ w_i x_ij²/n`. Returns max |Δβ|; `Err` only from a
/// store-backed source.
#[allow(clippy::too_many_arguments)]
fn wcd_cycle<C: ColAccess>(
    cols: &mut C,
    penalty: Penalty,
    lam: f64,
    active: &[usize],
    w: &[f64],
    xwx: &[f64],
    beta: &mut [f64],
    r: &mut [f64],
) -> Result<f64> {
    let n_inv = 1.0 / cols.nrows() as f64;
    let alpha = penalty.alpha();
    let l2 = penalty.l2_weight() * lam;
    let mut max_delta = 0.0f64;
    for &j in active {
        let col = cols.col(j)?;
        let mut grad = 0.0;
        for i in 0..col.len() {
            grad += w[i] * col[i] * r[i];
        }
        grad *= n_inv;
        let v = xwx[j];
        if v <= 0.0 {
            continue;
        }
        let z = grad + v * beta[j];
        let b_new = ops::soft_threshold(z, alpha * lam) / (v + l2);
        let delta = b_new - beta[j];
        if delta != 0.0 {
            ops::axpy(-delta, col, r);
            beta[j] = b_new;
            max_delta = max_delta.max(delta.abs() * v.sqrt().max(1.0));
        }
    }
    Ok(max_delta)
}

/// The ℓ1-logistic problem as a [`Problem`] instance: IRLS-wrapped
/// weighted coordinate descent over the strong set, with the score
/// residual `y − p̂` driving SSR screening and KKT checking through the
/// scan engine (GLM strong rules, Tibshirani et al. 2012 §7).
pub struct LogisticProblem<'a> {
    x: &'a DenseMatrix,
    y: &'a [f64],
    engine: &'a dyn ScanEngine,
    penalty: Penalty,
    rule: RuleKind,
    tol: f64,
    max_irls: usize,
    max_iter: usize,
    rescreen_every: usize,
    lambda_max: f64,
    // Minimal context (labels + penalty) for the logistic gap-safe rule.
    ctx: SafeContext,
    safe_rule: Option<Box<dyn SafeRule>>,
    b0: f64,
    beta: Vec<f64>,
    eta: Vec<f64>,
    // score_j = x_jᵀ(y − p̂)/n at the most recent iterate it was computed
    // at, maintained lazily like the Gaussian z.
    z: Vec<f64>,
    z_valid: Vec<bool>,
    // Scan residual y − p̂ at the current iterate (refreshed post-solve).
    resid: Vec<f64>,
    scratch: Vec<f64>,
    // Per-λ intercepts, collected by `end_lambda`.
    intercepts: Vec<f64>,
    // IRLS work buffers: weights, working residual, curvature diag.
    w: Vec<f64>,
    wr: Vec<f64>,
    xwx: Vec<f64>,
    // Engine columns scanned at construction (λmax + gap-safe
    // standardization checks) — folded into the first λ's `cols_scanned`
    // by the driver so scan accounting is exact, not off-by-the-preamble.
    preamble_cols: u64,
}

impl<'a> LogisticProblem<'a> {
    /// Build the problem at the null model `b = logit(ȳ)`, `β = 0`,
    /// validating the penalty, labels, and strategy.
    pub fn new(
        x: &'a DenseMatrix,
        y: &'a [f64],
        cfg: &LogisticPathConfig,
        engine: &'a dyn ScanEngine,
    ) -> Result<Self> {
        cfg.penalty.validate()?;
        if y.iter().any(|&v| v != 0.0 && v != 1.0) {
            return Err(HssrError::Config("logistic labels must be 0/1".into()));
        }
        if !matches!(
            cfg.rule,
            RuleKind::BasicPcd
                | RuleKind::ActiveCycling
                | RuleKind::Ssr
                | RuleKind::SsrGapSafe
        ) {
            return Err(HssrError::Config(format!(
                "logistic lasso supports Basic/AC/SSR/SSR-GapSafe (static quadratic-loss \
                 safe rules do not port; the dynamic gap-safe rule does), not {:?}",
                cfg.rule
            )));
        }
        if y.len() != x.nrows() {
            return Err(HssrError::Dimension("logistic: len(y) != nrows".into()));
        }
        let n = x.nrows();
        let p = x.ncols();
        let ybar = ops::mean(y);
        if ybar <= 0.0 || ybar >= 1.0 {
            return Err(HssrError::Config("labels are all one class".into()));
        }
        // Null model: b = logit(ȳ); score = Xᵀ(y − ȳ)/n gives λmax.
        let resid0: Vec<f64> = y.iter().map(|yi| yi - ybar).collect();
        let mut score0 = vec![0.0; p];
        engine.scan_all(x, &resid0, &mut score0)?;
        let mut preamble_cols = p as u64;
        let lambda_max = ops::inf_norm(&score0) / cfg.penalty.alpha();
        let safe_rule: Option<Box<dyn SafeRule>> = if cfg.rule == RuleKind::SsrGapSafe {
            // The gap-safe ball assumes standardization (2): centered
            // columns (the intercept's 1ᵀθ = 0 dual constraint) and
            // ‖x_j‖² = n (the radius term). The other logistic strategies
            // are scale-exact, so this is enforced only here — a safe rule
            // has no KKT backstop to catch a violated precondition.
            let ones = vec![1.0; n];
            let mut means = vec![0.0; p];
            engine.scan_all(x, &ones, &mut means)?; // x_jᵀ1/n
            preamble_cols += p as u64;
            for (j, &mj) in means.iter().enumerate() {
                let nrm = ops::nrm2_sq(x.col(j)) / n as f64;
                if mj.abs() > 1e-6 || (nrm - 1.0).abs() > 1e-6 {
                    return Err(HssrError::Config(format!(
                        "--rule ssr-gapsafe requires a standardized design \
                         (column {j}: mean {mj:.2e}, ‖x‖²/n = {nrm:.4}); \
                         standardize X or use basic/ac/ssr"
                    )));
                }
            }
            Some(Box::new(gapsafe::GapSafe::logistic()))
        } else {
            None
        };
        Ok(LogisticProblem {
            x,
            y,
            engine,
            penalty: cfg.penalty,
            rule: cfg.rule,
            tol: cfg.tol,
            max_irls: cfg.max_irls,
            max_iter: cfg.max_iter,
            rescreen_every: cfg.rescreen_every,
            lambda_max,
            ctx: gapsafe::logistic_context(y, p, lambda_max, cfg.penalty),
            safe_rule,
            b0: (ybar / (1.0 - ybar)).ln(),
            beta: vec![0.0; p],
            eta: vec![(ybar / (1.0 - ybar)).ln(); n],
            z: score0,
            z_valid: vec![true; p],
            resid: resid0,
            scratch: vec![0.0; p],
            intercepts: Vec::new(),
            w: vec![0.0; n],
            wr: vec![0.0; n],
            xwx: vec![0.0; p],
            preamble_cols,
        })
    }

    /// Whether the attached safe rule is dynamic (gap-safe).
    fn dynamic_rule(&self) -> bool {
        self.safe_rule.as_ref().map(|r| r.dynamic()).unwrap_or(false)
    }

    /// Materialize safe discards of still-live coefficients (support can
    /// shrink along the path): zero the coefficient, remove its
    /// contribution from `η`, refresh the score residual, and invalidate
    /// the lazy scores.
    fn zero_discarded(&mut self, survive: &[bool]) {
        let (x, beta, eta) = (self.x, &mut self.beta, &mut self.eta);
        let changed = zero_discarded_units(survive, |j| {
            if beta[j] != 0.0 {
                let b = beta[j];
                ops::axpy(-b, x.col(j), eta);
                beta[j] = 0.0;
                true
            } else {
                false
            }
        });
        if changed {
            for i in 0..self.eta.len() {
                self.resid[i] = self.y[i] - sigmoid(self.eta[i]);
            }
            self.z_valid.iter_mut().for_each(|v| *v = false);
        }
    }
}

impl Problem for LogisticProblem<'_> {
    fn n_units(&self) -> usize {
        self.beta.len()
    }

    fn n_coef(&self) -> usize {
        self.beta.len()
    }

    fn lambda_max(&self) -> f64 {
        self.lambda_max
    }

    fn has_safe_rule(&self) -> bool {
        // Static quadratic-loss safe rules do not port to this dual; the
        // dynamic gap-safe rule does (SsrGapSafe).
        self.safe_rule.is_some()
    }

    fn needs_kkt(&self) -> bool {
        !matches!(self.rule, RuleKind::BasicPcd)
    }

    fn preamble_cols(&self) -> u64 {
        self.preamble_cols
    }

    fn io_counters(&self) -> Option<&crate::data::store::StoreCounters> {
        self.engine.column_store().map(|s| s.counters())
    }

    /// λ-ahead prefetch: the GLM strong rule predicts λ_{k+1}'s working
    /// set from the current scores (active features always included);
    /// columns go to the engine's async prefetch service. Overlap only —
    /// a wrong prediction costs a wasted load, never correctness.
    fn prefetch_next(&mut self, lam: f64, lam_next: Option<f64>) {
        let Some(lam_next) = lam_next else { return };
        if self.engine.column_store().is_none() {
            return;
        }
        let t = ssr::threshold(self.penalty, lam_next, lam);
        let cols: Vec<usize> = (0..self.beta.len())
            .filter(|&j| {
                self.beta[j] != 0.0 || (self.z_valid[j] && self.z[j].abs() >= t)
            })
            .collect();
        self.engine.prefetch_columns(&cols);
    }

    fn screen(
        &mut self,
        lam: f64,
        lam_prev: f64,
        run_safe: bool,
        fused: bool,
        survive: &mut [bool],
        m: &mut LambdaMetrics,
    ) -> Result<ScreenStage> {
        let p = self.beta.len();
        let uses_ssr = self.rule.uses_ssr();
        let mut stage =
            ScreenStage { dynamic: self.dynamic_rule(), ..ScreenStage::default() };

        if fused && uses_ssr {
            // One traversal applies the gap-safe predicate (when attached),
            // refreshes stale scores over the survivors, and classifies
            // against the GLM strong threshold α(2λ − λ_prev).
            let ssr_t = ssr::threshold(self.penalty, lam, lam_prev);
            let mut masked_d = 0usize;
            let mut rule_scanned = 0u64;
            let fout = {
                let keep = if !run_safe {
                    None
                } else if let Some(rule) = self.safe_rule.as_mut() {
                    let prev = PrevSolution {
                        lambda: lam_prev,
                        r: &self.resid,
                        beta: Some(&self.beta),
                    };
                    rule.plan_routed(
                        self.engine,
                        self.x,
                        &self.ctx,
                        &prev,
                        lam,
                        survive,
                        &mut masked_d,
                        &mut rule_scanned,
                    )?
                } else {
                    None
                };
                self.engine.fused_screen(
                    self.x,
                    &self.resid,
                    keep.as_deref(),
                    ssr_t,
                    survive,
                    &mut self.z,
                    &mut self.z_valid,
                )?
            };
            m.cols_scanned += rule_scanned;
            stage.discarded = masked_d + fout.discarded;
            m.safe_size = fout.safe_size;
            m.cols_scanned += fout.cols_scanned;
            // glmnet-style ever-active inclusion: surviving active features
            // join H even when their score dips below the strong threshold.
            let mut keep = vec![false; p];
            for &j in &fout.strong {
                keep[j] = true;
            }
            stage.strong = (0..p)
                .filter(|&j| keep[j] || (survive[j] && self.beta[j] != 0.0))
                .collect();
            self.zero_discarded(survive);
            return Ok(stage);
        }

        if run_safe {
            if let Some(rule) = self.safe_rule.as_mut() {
                let prev = PrevSolution {
                    lambda: lam_prev,
                    r: &self.resid,
                    beta: Some(&self.beta),
                };
                let mut scanned = 0u64;
                stage.discarded = rule.screen_routed(
                    self.engine,
                    self.x,
                    &self.ctx,
                    &prev,
                    lam,
                    survive,
                    &mut scanned,
                )?;
                m.cols_scanned += scanned;
            }
        }
        m.safe_size = survive.iter().filter(|&&s| s).count();
        if uses_ssr {
            let stale: Vec<usize> =
                (0..p).filter(|&j| survive[j] && !self.z_valid[j]).collect();
            column_refresh(
                self.engine,
                self.x,
                &self.resid,
                &stale,
                &mut self.z,
                &mut self.z_valid,
                &mut self.scratch,
                m,
            )?;
        }
        stage.strong = match self.rule {
            RuleKind::BasicPcd => (0..p).collect(),
            RuleKind::ActiveCycling => {
                (0..p).filter(|&j| self.beta[j] != 0.0).collect()
            }
            _ => {
                let t = ssr::threshold(self.penalty, lam, lam_prev);
                (0..p)
                    .filter(|&j| {
                        survive[j] && (self.z[j].abs() >= t || self.beta[j] != 0.0)
                    })
                    .collect()
            }
        };
        self.zero_discarded(survive);
        Ok(stage)
    }

    fn solve(
        &mut self,
        lam: f64,
        lambda_index: usize,
        strong: &[usize],
        m: &mut LambdaMetrics,
    ) -> Result<()> {
        let n = self.x.nrows();
        let dynamic = self.rescreen_every > 0 && self.dynamic_rule();
        // The working set: fixed at `strong` for static strategies; pruned
        // between IRLS rounds by the dynamic gap-safe rule.
        let mut work: Vec<usize> = strong.to_vec();
        // ---- IRLS outer loop over the working set ----
        for irls in 0..self.max_irls {
            // weights + working residual at current (b0, beta)
            for i in 0..n {
                let pi = sigmoid(self.eta[i]);
                let wi = (pi * (1.0 - pi)).max(1e-5);
                self.w[i] = wi;
                self.wr[i] = (self.y[i] - pi) / wi;
            }
            // One column source per IRLS round serves the curvature pass,
            // the weighted CD cycles, and the η refresh; it drops before
            // the gap-safe rescreen so pinned chunks never overlap the
            // rule's engine scans (resident design natively, pinned store
            // cursor out-of-core — bit-identical bytes).
            let fit = {
                let mut cols = ColSource::for_engine(self.engine, self.x);
                for &j in &work {
                    let col = cols.col(j)?;
                    let mut s = 0.0;
                    for i in 0..n {
                        s += self.w[i] * col[i] * col[i];
                    }
                    self.xwx[j] = s / n as f64;
                }
                // intercept update (unpenalized)
                let sw: f64 = ops::sum(&self.w);
                let swr: f64 =
                    self.w.iter().zip(&self.wr).map(|(wi, ri)| wi * ri).sum();
                let db = swr / sw;
                if db != 0.0 {
                    self.b0 += db;
                    for ri in self.wr.iter_mut() {
                        *ri -= db;
                    }
                }
                // inner weighted CD
                let mut inner_delta = f64::INFINITY;
                for _ in 0..self.max_iter {
                    inner_delta = wcd_cycle(
                        &mut cols,
                        self.penalty,
                        lam,
                        &work,
                        &self.w,
                        &self.xwx,
                        &mut self.beta,
                        &mut self.wr,
                    )?;
                    m.cd_cycles += 1;
                    m.coord_updates += work.len() as u64;
                    if inner_delta < self.tol {
                        break;
                    }
                }
                if !inner_delta.is_finite() {
                    // NaN fails every `<`/`>=` comparison, so a poisoned
                    // surrogate would otherwise sail past both convergence
                    // checks as if it had converged — surface it as a typed,
                    // degradable divergence instead.
                    return Err(HssrError::NonFinite {
                        lambda_index,
                        context: "IRLS weighted-CD update delta".into(),
                    });
                }
                if inner_delta >= self.tol {
                    return Err(HssrError::NoConvergence {
                        lambda_index,
                        max_iter: self.max_iter,
                        last_delta: inner_delta,
                    });
                }
                // refresh η from scratch (cheap, avoids drift): η = b0 + Xβ
                columns::fit_eta(&mut cols, &self.beta)?
            };
            let mut outer_delta = 0.0f64;
            for i in 0..n {
                let new_eta = self.b0 + fit[i];
                outer_delta = outer_delta.max((new_eta - self.eta[i]).abs());
                self.eta[i] = new_eta;
            }
            if !outer_delta.is_finite() {
                return Err(HssrError::NonFinite {
                    lambda_index,
                    context: "IRLS linear predictor".into(),
                });
            }
            if outer_delta < 1e-8 {
                break;
            }
            // Dynamic re-fire between IRLS rounds (the logistic "epoch"):
            // the gap is computed at the *true* logistic iterate (not the
            // WLS surrogate), so discards are certified against this λ's
            // logistic optimum. Pruned coefficients are zeroed and removed
            // from η before the next round rebuilds the surrogate.
            if dynamic && !work.is_empty() && (irls + 1) % self.rescreen_every == 0 {
                for i in 0..n {
                    self.resid[i] = self.y[i] - sigmoid(self.eta[i]);
                }
                let mut keep = vec![true; self.beta.len()];
                if let Some(rule) = self.safe_rule.as_mut() {
                    let prev =
                        PrevSolution { lambda: lam, r: &self.resid, beta: Some(&self.beta) };
                    let mut scanned = 0u64;
                    rule.screen_routed(
                        self.engine,
                        self.x,
                        &self.ctx,
                        &prev,
                        lam,
                        &mut keep,
                        &mut scanned,
                    )?;
                    m.cols_scanned += scanned;
                }
                let (x, beta, eta) = (self.x, &mut self.beta, &mut self.eta);
                m.rescreen_discards += prune_working_set(&mut work, &keep, |j| {
                    if beta[j] != 0.0 {
                        let b = beta[j];
                        ops::axpy(-b, x.col(j), eta);
                        beta[j] = 0.0;
                    }
                });
            }
        }
        // Scan residual for screening/KKT: y − p̂ at the updated iterate.
        for i in 0..n {
            self.resid[i] = self.y[i] - sigmoid(self.eta[i]);
        }
        self.z_valid.iter_mut().for_each(|v| *v = false);
        Ok(())
    }

    fn rescreen(
        &mut self,
        lam: f64,
        survive: &mut [bool],
        in_strong: &[bool],
        m: &mut LambdaMetrics,
    ) -> Result<usize> {
        if !self.dynamic_rule() {
            return Ok(0);
        }
        let mut mask = survive.to_vec();
        if let Some(rule) = self.safe_rule.as_mut() {
            let prev = PrevSolution { lambda: lam, r: &self.resid, beta: Some(&self.beta) };
            let mut scanned = 0u64;
            rule.screen_routed(
                self.engine,
                self.x,
                &self.ctx,
                &prev,
                lam,
                &mut mask,
                &mut scanned,
            )?;
            m.cols_scanned += scanned;
        }
        let beta = &self.beta;
        Ok(apply_rescreen_mask(survive, &mask, in_strong, |j| beta[j] != 0.0))
    }

    fn kkt(
        &mut self,
        lam: f64,
        fused: bool,
        survive: &[bool],
        in_strong: &[bool],
        m: &mut LambdaMetrics,
    ) -> Result<Vec<usize>> {
        column_kkt(
            self.engine,
            self.x,
            &self.resid,
            self.penalty,
            lam,
            fused,
            survive,
            in_strong,
            &mut self.z,
            &mut self.z_valid,
            &mut self.scratch,
            m,
        )
    }

    fn end_lambda(
        &mut self,
        _lam: f64,
        fused: bool,
        strong: &[usize],
        m: &mut LambdaMetrics,
    ) -> Result<()> {
        // Unfused driver: refresh scores over the strong set so the next
        // SSR screening sees them at the final probabilities.
        let use_fused_kkt = fused && self.needs_kkt();
        if !use_fused_kkt && self.rule.uses_ssr() {
            column_refresh(
                self.engine,
                self.x,
                &self.resid,
                strong,
                &mut self.z,
                &mut self.z_valid,
                &mut self.scratch,
                m,
            )?;
        }
        self.intercepts.push(self.b0);
        Ok(())
    }

    fn sparse_beta(&self) -> Vec<(usize, f64)> {
        (0..self.beta.len())
            .filter(|&j| self.beta[j] != 0.0)
            .map(|j| (j, self.beta[j]))
            .collect()
    }

    fn objective(&self, lam: f64) -> f64 {
        let probs: Vec<f64> = self.eta.iter().map(|&e| sigmoid(e)).collect();
        deviance(self.y, &probs) / 2.0
            + self.penalty.alpha() * lam * self.beta.iter().map(|b| b.abs()).sum::<f64>()
            + self.penalty.l2_weight()
                * lam
                * 0.5
                * self.beta.iter().map(|b| b * b).sum::<f64>()
    }

    /// Everything a resumed λ step observes: the iterate `(b0, β, η)`, the
    /// score residual, the lazy scores *with* their validity mask (so
    /// `cols_scanned` reproduces bit-for-bit), the per-λ intercepts
    /// collected so far, and the safe rule's phase state.
    fn save_state(&self) -> Option<Vec<u8>> {
        let mut w = ByteWriter::new();
        w.put_f64(self.b0);
        w.put_f64s(&self.beta);
        w.put_f64s(&self.eta);
        w.put_f64s(&self.z);
        w.put_bools(&self.z_valid);
        w.put_f64s(&self.resid);
        w.put_f64s(&self.intercepts);
        let rule_state =
            self.safe_rule.as_ref().map(|ru| ru.save_state()).unwrap_or_default();
        w.put_blob(&rule_state);
        Some(w.into_bytes())
    }

    fn restore_state(&mut self, state: &[u8]) -> Result<()> {
        let mut rd = ByteReader::new(state);
        let b0 = rd.get_f64()?;
        let beta = rd.get_f64s()?;
        let eta = rd.get_f64s()?;
        let z = rd.get_f64s()?;
        let z_valid = rd.get_bools()?;
        let resid = rd.get_f64s()?;
        let intercepts = rd.get_f64s()?;
        let rule_state = rd.get_blob()?.to_vec();
        let (n, p) = (self.x.nrows(), self.x.ncols());
        if beta.len() != p
            || z.len() != p
            || z_valid.len() != p
            || eta.len() != n
            || resid.len() != n
        {
            return Err(HssrError::Corrupt(
                "logistic checkpoint state dimensions do not match the data".into(),
            ));
        }
        if let Some(rule) = self.safe_rule.as_mut() {
            rule.load_state(&rule_state)?;
        }
        self.b0 = b0;
        self.beta = beta;
        self.eta = eta;
        self.z = z;
        self.z_valid = z_valid;
        self.resid = resid;
        self.intercepts = intercepts;
        Ok(())
    }
}

/// Fit the ℓ1-logistic path with the default (native, pool-backed) scan
/// engine. `y` must be 0/1 labels (the Dataset's centered-`y` convention
/// does not apply; pass raw labels).
///
/// `RuleKind::SsrGapSafe` additionally requires a **standardized** design
/// (centered columns with `‖x_j‖² = n`, condition (2) — what
/// [`crate::data::standardize`] produces); this is validated at
/// construction. The other strategies are scale-exact.
pub fn fit_logistic_path(
    x: &DenseMatrix,
    y: &[f64],
    cfg: &LogisticPathConfig,
) -> Result<LogisticPathFit> {
    if let Some(engine) = ooc::env_engine_for(x, y)? {
        return fit_logistic_path_with_engine(x, y, cfg, &engine);
    }
    fit_logistic_path_with_engine(x, y, cfg, &NativeEngine::new())
}

/// Fit the ℓ1-logistic path with an explicit scan engine — every
/// screening/KKT scan dispatches through it on the shared pool.
pub fn fit_logistic_path_with_engine(
    x: &DenseMatrix,
    y: &[f64],
    cfg: &LogisticPathConfig,
    engine: &dyn ScanEngine,
) -> Result<LogisticPathFit> {
    let mut prob = LogisticProblem::new(x, y, cfg, engine)?;
    let fit = drive(&mut prob, &cfg.driver())?;
    Ok(LogisticPathFit {
        lambdas: fit.lambdas,
        intercepts: prob.intercepts,
        betas: fit.betas,
        metrics: fit.metrics,
        p: fit.p,
        lambda_max: fit.lambda_max,
        seconds: fit.seconds,
        rule: fit.rule,
        error: fit.error,
    })
}

/// Synthetic logistic workload: standardized Gaussian design, `s` true
/// features, labels `y ~ Bernoulli(σ(Xβ + b))`.
pub fn synthetic_logistic(
    n: usize,
    p: usize,
    s: usize,
    seed: u64,
) -> (DenseMatrix, Vec<f64>, Vec<usize>) {
    let mut rng = crate::rng::Pcg64::new(seed);
    let mut x = DenseMatrix::from_fn(n, p, |_, _| rng.normal());
    let mut dummy_y = vec![0.0; n];
    crate::data::standardize::standardize_in_place(&mut x, &mut dummy_y);
    let truth = {
        let mut t = rng.sample_indices(p, s.min(p));
        t.sort_unstable();
        t
    };
    let mut beta = vec![0.0; p];
    for &j in &truth {
        beta[j] = rng.uniform_in(0.5, 1.5) * if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
    }
    let eta = x.matvec(&beta);
    let y: Vec<f64> =
        eta.iter().map(|&e| if rng.bernoulli(sigmoid(e)) { 1.0 } else { 0.0 }).collect();
    (x, y, truth)
}

/// Convenience: standardized-design logistic fit from a [`Dataset`]-like
/// pair where `y` holds 0/1 labels.
pub fn fit_logistic_from_dataset(
    ds: &Dataset,
    labels: &[f64],
    cfg: &LogisticPathConfig,
) -> Result<LogisticPathFit> {
    fit_logistic_path(&ds.x, labels, cfg)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::linalg::blocked;

    fn fit(n: usize, p: usize, rule: RuleKind, seed: u64) -> (DenseMatrix, Vec<f64>, LogisticPathFit) {
        let (x, y, _) = synthetic_logistic(n, p, 5, seed);
        let cfg = LogisticPathConfig { rule, n_lambda: 25, tol: 1e-9, ..Default::default() };
        let fit = fit_logistic_path(&x, &y, &cfg).unwrap();
        (x, y, fit)
    }

    #[test]
    fn sigmoid_sane() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-12);
        assert!(sigmoid(30.0) > 0.999999);
        assert!(sigmoid(-30.0) < 1e-6);
    }

    #[test]
    fn null_solution_at_lambda_max() {
        let (_, _, fit) = fit(120, 60, RuleKind::Ssr, 1);
        assert_eq!(fit.betas[0].len(), 0, "β(λmax) must be 0");
        assert!(fit.betas.last().unwrap().len() > 0);
    }

    #[test]
    fn kkt_holds_along_path() {
        let (x, y, fit) = fit(150, 50, RuleKind::Ssr, 2);
        for (k, &lam) in fit.lambdas.iter().enumerate().step_by(6) {
            let probs = fit.predict_proba(&x, k);
            let resid: Vec<f64> = y.iter().zip(&probs).map(|(yi, pi)| yi - pi).collect();
            let z = blocked::scan_all_vec(&x, &resid);
            let beta = fit.beta_dense(k);
            for j in 0..x.ncols() {
                if beta[j] != 0.0 {
                    assert!(
                        (z[j] - lam * beta[j].signum()).abs() < 1e-4,
                        "λ#{k} active {j}: z={}",
                        z[j]
                    );
                } else {
                    assert!(z[j].abs() <= lam * (1.0 + 1e-3) + 1e-7, "λ#{k} inactive {j}");
                }
            }
            // intercept optimality: Σ(y − p) = 0
            let score0: f64 = resid.iter().sum();
            assert!(score0.abs() / x.nrows() as f64 <= 1e-6, "intercept score {score0}");
        }
    }

    #[test]
    fn strategies_agree() {
        let (_, _, basic) = fit(100, 40, RuleKind::BasicPcd, 3);
        for rule in [RuleKind::ActiveCycling, RuleKind::Ssr, RuleKind::SsrGapSafe] {
            let (_, _, other) = fit(100, 40, rule, 3);
            for k in 0..basic.lambdas.len() {
                let a = basic.beta_dense(k);
                let b = other.beta_dense(k);
                for j in 0..a.len() {
                    assert!((a[j] - b[j]).abs() < 1e-4, "{rule:?} λ#{k} β[{j}]");
                }
                assert!((basic.intercepts[k] - other.intercepts[k]).abs() < 1e-4);
            }
        }
    }

    /// The fused and unfused logistic pipelines must select exactly the
    /// same features and produce identical paths (the randomized version
    /// lives in `crate::prop`).
    #[test]
    fn fused_logistic_bit_identical_to_unfused() {
        let (x, y, _) = synthetic_logistic(120, 60, 5, 9);
        for rule in [
            RuleKind::BasicPcd,
            RuleKind::ActiveCycling,
            RuleKind::Ssr,
            RuleKind::SsrGapSafe,
        ] {
            let cfg = LogisticPathConfig {
                rule,
                n_lambda: 20,
                tol: 1e-9,
                fused: true,
                ..Default::default()
            };
            let fused = fit_logistic_path(&x, &y, &cfg).unwrap();
            let unfused = fit_logistic_path(
                &x,
                &y,
                &LogisticPathConfig { fused: false, ..cfg },
            )
            .unwrap();
            assert_eq!(fused.betas, unfused.betas, "{rule:?} betas differ");
            assert_eq!(fused.intercepts, unfused.intercepts, "{rule:?} intercepts");
            for (k, (mf, mu)) in
                fused.metrics.iter().zip(unfused.metrics.iter()).enumerate()
            {
                assert_eq!(mf.strong_size, mu.strong_size, "{rule:?} |H| at λ#{k}");
                assert_eq!(mf.violations, mu.violations, "{rule:?} viols at λ#{k}");
            }
        }
    }

    /// The first safe-screened GLM path: SSR-GapSafe actually screens
    /// (|S| < p somewhere on the path), re-fires dynamically, and matches
    /// the exact solution.
    #[test]
    fn gapsafe_logistic_screens_and_stays_exact() {
        let (x, y, _) = synthetic_logistic(150, 80, 5, 8);
        let cfg = LogisticPathConfig {
            rule: RuleKind::SsrGapSafe,
            n_lambda: 25,
            tol: 1e-9,
            ..Default::default()
        };
        let fit = fit_logistic_path(&x, &y, &cfg).unwrap();
        let basic = fit_logistic_path(
            &x,
            &y,
            &LogisticPathConfig { rule: RuleKind::BasicPcd, ..cfg.clone() },
        )
        .unwrap();
        for k in 0..fit.lambdas.len() {
            let a = fit.beta_dense(k);
            let b = basic.beta_dense(k);
            for j in 0..x.ncols() {
                assert!((a[j] - b[j]).abs() < 1e-4, "λ#{k} β[{j}] deviates");
            }
            assert!((fit.intercepts[k] - basic.intercepts[k]).abs() < 1e-4);
        }
        assert!(
            fit.metrics.iter().any(|m| m.safe_size < x.ncols()),
            "gap-safe never screened a logistic λ step"
        );
    }

    #[test]
    fn recovers_signal_features() {
        let (x, y, truth) = synthetic_logistic(400, 60, 4, 4);
        let cfg = LogisticPathConfig { n_lambda: 30, ..Default::default() };
        let fit = fit_logistic_path(&x, &y, &cfg).unwrap();
        let sel: Vec<usize> =
            fit.betas.last().unwrap().iter().map(|&(j, _)| j).collect();
        let hits = truth.iter().filter(|j| sel.contains(j)).count();
        assert!(hits >= 3, "recovered {hits}/4 true features; selected {sel:?}");
    }

    #[test]
    fn deviance_decreases_along_path() {
        let (x, y, fit) = fit(150, 50, RuleKind::Ssr, 5);
        let d_first = deviance(&y, &fit.predict_proba(&x, 1));
        let d_last = deviance(&y, &fit.predict_proba(&x, fit.lambdas.len() - 1));
        assert!(d_last < d_first, "{d_last} !< {d_first}");
    }

    #[test]
    fn rejects_bad_inputs() {
        let (x, mut y, _) = synthetic_logistic(50, 20, 3, 6);
        let cfg = LogisticPathConfig::default();
        y[0] = 0.5;
        assert!(matches!(
            fit_logistic_path(&x, &y, &cfg),
            Err(HssrError::Config(_))
        ));
        y[0] = 1.0;
        let bad = LogisticPathConfig { rule: RuleKind::SsrBedpp, ..Default::default() };
        assert!(matches!(fit_logistic_path(&x, &y, &bad), Err(HssrError::Config(_))));
        let ones = vec![1.0; 50];
        assert!(matches!(fit_logistic_path(&x, &ones, &cfg), Err(HssrError::Config(_))));
    }

    /// The gap-safe strategy validates standardization (2) up front — the
    /// one precondition the scale-exact strategies don't need.
    #[test]
    fn gapsafe_requires_standardized_design() {
        let (x, y, _) = synthetic_logistic(60, 20, 3, 10);
        // Break standardization: rescale one column.
        let raw = DenseMatrix::from_fn(60, 20, |i, j| {
            x.get(i, j) * if j == 3 { 2.0 } else { 1.0 }
        });
        let cfg = LogisticPathConfig { rule: RuleKind::SsrGapSafe, ..Default::default() };
        assert!(matches!(
            fit_logistic_path(&raw, &y, &cfg),
            Err(HssrError::Config(_))
        ));
        // The standardized design passes the same validation.
        let ok = fit_logistic_path(&x, &y, &LogisticPathConfig { n_lambda: 5, ..cfg });
        assert!(ok.is_ok());
    }

    /// A path interrupted mid-grid and resumed from its checkpoint must
    /// reproduce the uninterrupted fit bit-for-bit — coefficients,
    /// intercepts, and per-λ instrumentation — for the first
    /// safe-screened GLM family too.
    #[test]
    fn checkpoint_resume_is_bit_identical() {
        let dir = std::env::temp_dir().join("hssr_logistic_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let (x, y, _) = synthetic_logistic(120, 60, 5, 12);
        for rule in [RuleKind::Ssr, RuleKind::SsrGapSafe] {
            let cfg = LogisticPathConfig {
                rule,
                n_lambda: 20,
                tol: 1e-9,
                ..Default::default()
            };
            let full = fit_logistic_path(&x, &y, &cfg).unwrap();
            let grid = full.lambdas.clone();
            let ckpt = dir.join(format!("logistic-{rule:?}.ckpt"));
            let _ = std::fs::remove_file(&ckpt);
            // "Crash" after 8 of 20 λs: fit only the grid prefix,
            // checkpointing each step.
            let prefix = fit_logistic_path(
                &x,
                &y,
                &LogisticPathConfig {
                    lambdas: Some(grid[..8].to_vec()),
                    checkpoint: Some(ckpt.clone()),
                    ..cfg.clone()
                },
            )
            .unwrap();
            assert_eq!(prefix.betas.len(), 8, "{rule:?} prefix length");
            // Resume over the full grid from the survived checkpoint.
            let resumed = fit_logistic_path(
                &x,
                &y,
                &LogisticPathConfig {
                    lambdas: Some(grid.clone()),
                    checkpoint: Some(ckpt.clone()),
                    ..cfg.clone()
                },
            )
            .unwrap();
            assert_eq!(resumed.lambdas, full.lambdas, "{rule:?} λ grid");
            assert_eq!(resumed.betas, full.betas, "{rule:?} betas");
            assert_eq!(resumed.intercepts, full.intercepts, "{rule:?} intercepts");
            assert_eq!(resumed.metrics, full.metrics, "{rule:?} per-λ metrics");
            std::fs::remove_file(&ckpt).unwrap();
        }
    }

    #[test]
    fn elastic_net_penalty_supported() {
        let (x, y, _) = synthetic_logistic(100, 30, 4, 7);
        let cfg = LogisticPathConfig {
            penalty: Penalty::ElasticNet { alpha: 0.5 },
            n_lambda: 15,
            ..Default::default()
        };
        let fit = fit_logistic_path(&x, &y, &cfg).unwrap();
        assert!(fit.betas.last().unwrap().len() > 0);
    }
}
