//! Sparse logistic regression — the paper's §6 future-work extension
//! ("we are currently working on extending the hybrid screening idea to
//! other lasso-type problems such as sparse logistic regression").
//!
//! The ℓ1-penalized logistic model is
//!
//! ```text
//! min_{b,β}  (1/n) Σᵢ [ log(1 + e^{ηᵢ}) − yᵢηᵢ ]  +  λα‖β‖₁ + λ(1−α)/2‖β‖²,
//! ηᵢ = b + xᵢᵀβ,   yᵢ ∈ {0,1},
//! ```
//!
//! solved by IRLS-wrapped coordinate descent (glmnet/biglasso style): each
//! outer iteration builds the weighted least-squares surrogate at the
//! current `(b, β)` and runs penalized weighted CD to convergence.
//!
//! The *sequential strong rule* carries over directly (Tibshirani et al.
//! 2012 §7): discard `j` at `λ_{k+1}` if `|x_jᵀ(y − p̂(λ_k))/n| <
//! α(2λ_{k+1} − λ_k)`, with post-convergence KKT checking against
//! `|x_jᵀ(y − p̂)/n| ≤ αλ`. The quadratic-loss safe rules (BEDPP/Dome/
//! SEDPP) do **not** port — their dual geometry is specific to the squared
//! loss — so the supported strategies are Basic, AC, and SSR (exactly the
//! state the paper leaves this extension in).

use std::time::Instant;

use crate::data::Dataset;
use crate::error::{HssrError, Result};
use crate::linalg::{blocked, ops, DenseMatrix};
use crate::screening::RuleKind;
use crate::solver::lambda::GridKind;
use crate::solver::path::LambdaMetrics;
use crate::solver::Penalty;

/// Configuration for the logistic path.
#[derive(Clone, Debug)]
pub struct LogisticPathConfig {
    /// Strategy: `BasicPcd`, `ActiveCycling`, or `Ssr`.
    pub rule: RuleKind,
    /// Penalty (α mixing).
    pub penalty: Penalty,
    /// Grid points.
    pub n_lambda: usize,
    /// λmin/λmax ratio.
    pub lambda_min_ratio: f64,
    /// Grid spacing.
    pub grid: GridKind,
    /// CD convergence tolerance.
    pub tol: f64,
    /// Max outer IRLS iterations per λ.
    pub max_irls: usize,
    /// Max CD cycles per IRLS step.
    pub max_iter: usize,
}

impl Default for LogisticPathConfig {
    fn default() -> Self {
        LogisticPathConfig {
            rule: RuleKind::Ssr,
            penalty: Penalty::Lasso,
            n_lambda: 100,
            lambda_min_ratio: 0.05,
            grid: GridKind::Log,
            tol: 1e-7,
            max_irls: 50,
            max_iter: 10_000,
        }
    }
}

/// Result of a logistic path fit.
#[derive(Clone, Debug)]
pub struct LogisticPathFit {
    /// λ grid.
    pub lambdas: Vec<f64>,
    /// Intercept per λ.
    pub intercepts: Vec<f64>,
    /// Sparse coefficients per λ.
    pub betas: Vec<Vec<(usize, f64)>>,
    /// Per-λ instrumentation.
    pub metrics: Vec<LambdaMetrics>,
    /// Features.
    pub p: usize,
    /// λmax.
    pub lambda_max: f64,
    /// Wall-clock seconds.
    pub seconds: f64,
    /// Strategy.
    pub rule: RuleKind,
}

impl LogisticPathFit {
    /// Dense coefficients at grid index `k`.
    pub fn beta_dense(&self, k: usize) -> Vec<f64> {
        let mut b = vec![0.0; self.p];
        for &(j, v) in &self.betas[k] {
            b[j] = v;
        }
        b
    }

    /// Predicted probabilities on the (standardized) design at index `k`.
    pub fn predict_proba(&self, x: &DenseMatrix, k: usize) -> Vec<f64> {
        let beta = self.beta_dense(k);
        let mut eta = x.matvec(&beta);
        for e in eta.iter_mut() {
            *e = sigmoid(*e + self.intercepts[k]);
        }
        eta
    }
}

#[inline]
fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Binomial deviance (−2·loglik/n) of probabilities `p` against labels `y`.
pub fn deviance(y: &[f64], p: &[f64]) -> f64 {
    let eps = 1e-12;
    let mut d = 0.0;
    for (yi, pi) in y.iter().zip(p) {
        let pi = pi.clamp(eps, 1.0 - eps);
        d -= 2.0 * (yi * pi.ln() + (1.0 - yi) * (1.0 - pi).ln());
    }
    d / y.len() as f64
}

/// One weighted CD cycle on the IRLS surrogate. `w` are the IRLS weights,
/// `r` is the working residual `z − η` (maintained exactly), `xwx[j] =
/// Σ w_i x_ij²/n`. Returns max |Δβ|.
#[allow(clippy::too_many_arguments)]
fn wcd_cycle(
    x: &DenseMatrix,
    penalty: Penalty,
    lam: f64,
    active: &[usize],
    w: &[f64],
    xwx: &[f64],
    beta: &mut [f64],
    r: &mut [f64],
) -> f64 {
    let n_inv = 1.0 / x.nrows() as f64;
    let alpha = penalty.alpha();
    let l2 = penalty.l2_weight() * lam;
    let mut max_delta = 0.0f64;
    for &j in active {
        let col = x.col(j);
        let mut grad = 0.0;
        for i in 0..col.len() {
            grad += w[i] * col[i] * r[i];
        }
        grad *= n_inv;
        let v = xwx[j];
        if v <= 0.0 {
            continue;
        }
        let z = grad + v * beta[j];
        let b_new = ops::soft_threshold(z, alpha * lam) / (v + l2);
        let delta = b_new - beta[j];
        if delta != 0.0 {
            ops::axpy(-delta, col, r);
            beta[j] = b_new;
            max_delta = max_delta.max(delta.abs() * v.sqrt().max(1.0));
        }
    }
    max_delta
}

/// Fit the ℓ1-logistic path. `y` must be 0/1 labels (the Dataset's
/// centered-`y` convention does not apply; pass raw labels).
pub fn fit_logistic_path(
    x: &DenseMatrix,
    y: &[f64],
    cfg: &LogisticPathConfig,
) -> Result<LogisticPathFit> {
    cfg.penalty.validate()?;
    if y.iter().any(|&v| v != 0.0 && v != 1.0) {
        return Err(HssrError::Config("logistic labels must be 0/1".into()));
    }
    if !matches!(cfg.rule, RuleKind::BasicPcd | RuleKind::ActiveCycling | RuleKind::Ssr) {
        return Err(HssrError::Config(format!(
            "logistic lasso supports Basic/AC/SSR (quadratic-loss safe rules do not port), not {:?}",
            cfg.rule
        )));
    }
    let start = Instant::now();
    let n = x.nrows();
    let p = x.ncols();
    if y.len() != n {
        return Err(HssrError::Dimension("logistic: len(y) != nrows".into()));
    }
    let ybar = ops::mean(y);
    if ybar <= 0.0 || ybar >= 1.0 {
        return Err(HssrError::Config("labels are all one class".into()));
    }
    // Null model: b = logit(ȳ); score = Xᵀ(y − ȳ)/n gives λmax.
    let resid0: Vec<f64> = y.iter().map(|yi| yi - ybar).collect();
    let score0 = blocked::scan_all_vec(x, &resid0);
    let lambda_max = ops::inf_norm(&score0) / cfg.penalty.alpha();
    let lambdas =
        crate::solver::lambda::grid(lambda_max, cfg.lambda_min_ratio, cfg.n_lambda, cfg.grid);

    let mut b0 = (ybar / (1.0 - ybar)).ln();
    let mut beta = vec![0.0; p];
    let mut eta = vec![b0; n];
    // score_j = x_jᵀ(y − p̂)/n at the most recent solution (all valid at null).
    let mut score = score0;
    let mut betas = Vec::with_capacity(lambdas.len());
    let mut intercepts = Vec::with_capacity(lambdas.len());
    let mut metrics = Vec::with_capacity(lambdas.len());

    let mut lam_prev = lambda_max;
    for (k, &lam) in lambdas.iter().enumerate() {
        let mut m = LambdaMetrics { lambda: lam, safe_size: p, ..Default::default() };
        let alpha = cfg.penalty.alpha();
        // ---- screening ----
        let mut strong: Vec<usize> = match cfg.rule {
            RuleKind::BasicPcd => (0..p).collect(),
            RuleKind::ActiveCycling => (0..p).filter(|&j| beta[j] != 0.0).collect(),
            _ => {
                let t = alpha * (2.0 * lam - lam_prev);
                (0..p).filter(|&j| score[j].abs() >= t || beta[j] != 0.0).collect()
            }
        };
        let mut in_strong = vec![false; p];
        for &j in &strong {
            in_strong[j] = true;
        }

        loop {
            // ---- IRLS outer loop over the strong set ----
            let mut w = vec![0.0; n];
            let mut r = vec![0.0; n];
            let mut xwx = vec![0.0; p];
            for _irls in 0..cfg.max_irls {
                // weights + working residual at current (b0, beta)
                let mut max_w: f64 = 0.0;
                for i in 0..n {
                    let pi = sigmoid(eta[i]);
                    let wi = (pi * (1.0 - pi)).max(1e-5);
                    w[i] = wi;
                    r[i] = (y[i] - pi) / wi;
                    max_w = max_w.max(wi);
                }
                for &j in &strong {
                    let col = x.col(j);
                    let mut s = 0.0;
                    for i in 0..n {
                        s += w[i] * col[i] * col[i];
                    }
                    xwx[j] = s / n as f64;
                }
                // intercept update (unpenalized)
                let sw: f64 = ops::sum(&w);
                let swr: f64 = w.iter().zip(&r).map(|(wi, ri)| wi * ri).sum();
                let db = swr / sw;
                if db != 0.0 {
                    b0 += db;
                    for ri in r.iter_mut() {
                        *ri -= db;
                    }
                }
                // inner weighted CD
                let mut inner_delta = f64::INFINITY;
                for _ in 0..cfg.max_iter {
                    inner_delta =
                        wcd_cycle(x, cfg.penalty, lam, &strong, &w, &xwx, &mut beta, &mut r);
                    m.cd_cycles += 1;
                    m.coord_updates += strong.len() as u64;
                    if inner_delta < cfg.tol {
                        break;
                    }
                }
                if inner_delta >= cfg.tol {
                    return Err(HssrError::NoConvergence {
                        lambda_index: k,
                        max_iter: cfg.max_iter,
                        last_delta: inner_delta,
                    });
                }
                // refresh η from scratch (cheap, avoids drift): η = b0 + Xβ
                let fit = x.matvec(&beta);
                let mut outer_delta = 0.0f64;
                for i in 0..n {
                    let new_eta = b0 + fit[i];
                    outer_delta = outer_delta.max((new_eta - eta[i]).abs());
                    eta[i] = new_eta;
                }
                if outer_delta < 1e-8 {
                    break;
                }
            }
            // ---- KKT check over the complement ----
            let resid: Vec<f64> = (0..n).map(|i| y[i] - sigmoid(eta[i])).collect();
            let check: Vec<usize> = match cfg.rule {
                RuleKind::BasicPcd => Vec::new(),
                _ => (0..p).filter(|&j| !in_strong[j]).collect(),
            };
            if check.is_empty() {
                // refresh score over strong set for the next SSR step
                let mut s = vec![0.0; strong.len()];
                blocked::scan_subset(x, &resid, &strong, &mut s);
                for (i, &j) in strong.iter().enumerate() {
                    score[j] = s[i];
                }
                break;
            }
            let mut zc = vec![0.0; check.len()];
            blocked::scan_subset(x, &resid, &check, &mut zc);
            m.cols_scanned += check.len() as u64;
            m.kkt_checked += check.len();
            let mut viols = Vec::new();
            for (i, &j) in check.iter().enumerate() {
                score[j] = zc[i];
                if zc[i].abs() > alpha * lam * (1.0 + 1e-7) {
                    viols.push(j);
                }
            }
            // refresh strong-set scores too
            let mut s = vec![0.0; strong.len()];
            blocked::scan_subset(x, &resid, &strong, &mut s);
            for (i, &j) in strong.iter().enumerate() {
                score[j] = s[i];
            }
            if viols.is_empty() {
                break;
            }
            m.violations += viols.len();
            for &j in &viols {
                in_strong[j] = true;
            }
            strong.extend(viols);
        }

        m.strong_size = strong.len();
        let sparse: Vec<(usize, f64)> =
            (0..p).filter(|&j| beta[j] != 0.0).map(|j| (j, beta[j])).collect();
        m.nonzero = sparse.len();
        let probs: Vec<f64> = eta.iter().map(|&e| sigmoid(e)).collect();
        m.objective = deviance(y, &probs) / 2.0
            + cfg.penalty.alpha() * lam * beta.iter().map(|b| b.abs()).sum::<f64>()
            + cfg.penalty.l2_weight() * lam * 0.5 * beta.iter().map(|b| b * b).sum::<f64>();
        betas.push(sparse);
        intercepts.push(b0);
        metrics.push(m);
        lam_prev = lam;
    }
    Ok(LogisticPathFit {
        lambdas,
        intercepts,
        betas,
        metrics,
        p,
        lambda_max,
        seconds: start.elapsed().as_secs_f64(),
        rule: cfg.rule,
    })
}

/// Synthetic logistic workload: standardized Gaussian design, `s` true
/// features, labels `y ~ Bernoulli(σ(Xβ + b))`.
pub fn synthetic_logistic(
    n: usize,
    p: usize,
    s: usize,
    seed: u64,
) -> (DenseMatrix, Vec<f64>, Vec<usize>) {
    let mut rng = crate::rng::Pcg64::new(seed);
    let mut x = DenseMatrix::from_fn(n, p, |_, _| rng.normal());
    let mut dummy_y = vec![0.0; n];
    crate::data::standardize::standardize_in_place(&mut x, &mut dummy_y);
    let truth = {
        let mut t = rng.sample_indices(p, s.min(p));
        t.sort_unstable();
        t
    };
    let mut beta = vec![0.0; p];
    for &j in &truth {
        beta[j] = rng.uniform_in(0.5, 1.5) * if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
    }
    let eta = x.matvec(&beta);
    let y: Vec<f64> =
        eta.iter().map(|&e| if rng.bernoulli(sigmoid(e)) { 1.0 } else { 0.0 }).collect();
    (x, y, truth)
}

/// Convenience: standardized-design logistic fit from a [`Dataset`]-like
/// pair where `y` holds 0/1 labels.
pub fn fit_logistic_from_dataset(
    ds: &Dataset,
    labels: &[f64],
    cfg: &LogisticPathConfig,
) -> Result<LogisticPathFit> {
    fit_logistic_path(&ds.x, labels, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fit(n: usize, p: usize, rule: RuleKind, seed: u64) -> (DenseMatrix, Vec<f64>, LogisticPathFit) {
        let (x, y, _) = synthetic_logistic(n, p, 5, seed);
        let cfg = LogisticPathConfig { rule, n_lambda: 25, tol: 1e-9, ..Default::default() };
        let fit = fit_logistic_path(&x, &y, &cfg).unwrap();
        (x, y, fit)
    }

    #[test]
    fn sigmoid_sane() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-12);
        assert!(sigmoid(30.0) > 0.999999);
        assert!(sigmoid(-30.0) < 1e-6);
    }

    #[test]
    fn null_solution_at_lambda_max() {
        let (_, _, fit) = fit(120, 60, RuleKind::Ssr, 1);
        assert_eq!(fit.betas[0].len(), 0, "β(λmax) must be 0");
        assert!(fit.betas.last().unwrap().len() > 0);
    }

    #[test]
    fn kkt_holds_along_path() {
        let (x, y, fit) = fit(150, 50, RuleKind::Ssr, 2);
        for (k, &lam) in fit.lambdas.iter().enumerate().step_by(6) {
            let probs = fit.predict_proba(&x, k);
            let resid: Vec<f64> = y.iter().zip(&probs).map(|(yi, pi)| yi - pi).collect();
            let z = blocked::scan_all_vec(&x, &resid);
            let beta = fit.beta_dense(k);
            for j in 0..x.ncols() {
                if beta[j] != 0.0 {
                    assert!(
                        (z[j] - lam * beta[j].signum()).abs() < 1e-4,
                        "λ#{k} active {j}: z={}",
                        z[j]
                    );
                } else {
                    assert!(z[j].abs() <= lam * (1.0 + 1e-3) + 1e-7, "λ#{k} inactive {j}");
                }
            }
            // intercept optimality: Σ(y − p) = 0
            let score0: f64 = resid.iter().sum();
            assert!(score0.abs() / x.nrows() as f64 <= 1e-6, "intercept score {score0}");
        }
    }

    #[test]
    fn strategies_agree() {
        let (_, _, basic) = fit(100, 40, RuleKind::BasicPcd, 3);
        for rule in [RuleKind::ActiveCycling, RuleKind::Ssr] {
            let (_, _, other) = fit(100, 40, rule, 3);
            for k in 0..basic.lambdas.len() {
                let a = basic.beta_dense(k);
                let b = other.beta_dense(k);
                for j in 0..a.len() {
                    assert!((a[j] - b[j]).abs() < 1e-4, "{rule:?} λ#{k} β[{j}]");
                }
                assert!((basic.intercepts[k] - other.intercepts[k]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn recovers_signal_features() {
        let (x, y, truth) = synthetic_logistic(400, 60, 4, 4);
        let cfg = LogisticPathConfig { n_lambda: 30, ..Default::default() };
        let fit = fit_logistic_path(&x, &y, &cfg).unwrap();
        let sel: Vec<usize> =
            fit.betas.last().unwrap().iter().map(|&(j, _)| j).collect();
        let hits = truth.iter().filter(|j| sel.contains(j)).count();
        assert!(hits >= 3, "recovered {hits}/4 true features; selected {sel:?}");
    }

    #[test]
    fn deviance_decreases_along_path() {
        let (x, y, fit) = fit(150, 50, RuleKind::Ssr, 5);
        let d_first = deviance(&y, &fit.predict_proba(&x, 1));
        let d_last = deviance(&y, &fit.predict_proba(&x, fit.lambdas.len() - 1));
        assert!(d_last < d_first, "{d_last} !< {d_first}");
    }

    #[test]
    fn rejects_bad_inputs() {
        let (x, mut y, _) = synthetic_logistic(50, 20, 3, 6);
        let cfg = LogisticPathConfig::default();
        y[0] = 0.5;
        assert!(matches!(
            fit_logistic_path(&x, &y, &cfg),
            Err(HssrError::Config(_))
        ));
        y[0] = 1.0;
        let bad = LogisticPathConfig { rule: RuleKind::SsrBedpp, ..Default::default() };
        assert!(matches!(fit_logistic_path(&x, &y, &bad), Err(HssrError::Config(_))));
        let ones = vec![1.0; 50];
        assert!(matches!(fit_logistic_path(&x, &ones, &cfg), Err(HssrError::Config(_))));
    }

    #[test]
    fn elastic_net_penalty_supported() {
        let (x, y, _) = synthetic_logistic(100, 30, 4, 7);
        let cfg = LogisticPathConfig {
            penalty: Penalty::ElasticNet { alpha: 0.5 },
            n_lambda: 15,
            ..Default::default()
        };
        let fit = fit_logistic_path(&x, &y, &cfg).unwrap();
        assert!(fit.betas.last().unwrap().len() > 0);
    }
}
