//! λ-grid construction.
//!
//! The paper's experiments use **100 values equally spaced on the λ/λmax
//! scale from 0.1 to 1** (§5); glmnet-style log-spaced grids are also
//! provided for users.

/// Grid spacing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GridKind {
    /// Equally spaced on λ/λmax (the paper's protocol).
    Linear,
    /// Equally spaced on log λ (glmnet default).
    Log,
}

/// Build a decreasing grid of `k` values from `lambda_max` down to
/// `ratio_min · lambda_max` (inclusive at both ends).
pub fn grid(lambda_max: f64, ratio_min: f64, k: usize, kind: GridKind) -> Vec<f64> {
    assert!(k >= 2, "grid needs at least 2 points");
    assert!(lambda_max > 0.0 && ratio_min > 0.0 && ratio_min < 1.0);
    match kind {
        GridKind::Linear => (0..k)
            .map(|i| {
                let f = 1.0 - (1.0 - ratio_min) * i as f64 / (k - 1) as f64;
                lambda_max * f
            })
            .collect(),
        GridKind::Log => {
            let lmin = (ratio_min * lambda_max).ln();
            let lmax = lambda_max.ln();
            (0..k)
                .map(|i| (lmax + (lmin - lmax) * i as f64 / (k - 1) as f64).exp())
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_grid_endpoints_and_monotone() {
        let g = grid(2.0, 0.1, 100, GridKind::Linear);
        assert_eq!(g.len(), 100);
        assert!((g[0] - 2.0).abs() < 1e-12);
        assert!((g[99] - 0.2).abs() < 1e-12);
        for w in g.windows(2) {
            assert!(w[0] > w[1]);
        }
        // equal spacing
        let d0 = g[0] - g[1];
        let d50 = g[50] - g[51];
        assert!((d0 - d50).abs() < 1e-12);
    }

    #[test]
    fn log_grid_endpoints_and_ratio() {
        let g = grid(1.0, 0.01, 5, GridKind::Log);
        assert!((g[0] - 1.0).abs() < 1e-12);
        assert!((g[4] - 0.01).abs() < 1e-12);
        // constant ratio
        let r0 = g[1] / g[0];
        let r3 = g[4] / g[3];
        assert!((r0 - r3).abs() < 1e-12);
    }
}
