//! Lasso-type solvers: coordinate descent inner loops, blockwise group
//! descent, and the pathwise orchestration of Algorithm 1.
//!
//! The Algorithm-1 λ-loop itself is written **once**, in [`driver`], as a
//! generic `PathDriver` over the [`driver::Problem`] trait; [`path`]
//! (lasso/elastic net), [`group_path`] (group lasso), and [`logistic`]
//! (ℓ1-logistic, §6) are `Problem` instances plus thin config shims.

// Solvers must degrade through typed errors (`PathError`, `NonFinite`),
// never panic mid-path. Test modules opt back out.
#![deny(clippy::unwrap_used)]

pub mod cd;
pub mod columns;
pub mod driver;
pub mod duality;
pub mod gd;
pub mod group_path;
pub mod kkt;
pub mod lambda;
pub mod logistic;
pub mod path;

/// The penalty family. `Lasso` is `ElasticNet { alpha: 1.0 }` but kept as a
/// distinct variant so the common case avoids the enet bookkeeping.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Penalty {
    /// `λ‖β‖₁` (problem (1) of the paper).
    Lasso,
    /// `αλ‖β‖₁ + (1−α)λ/2·‖β‖²` (problem (13)); `0 < alpha <= 1`.
    ElasticNet {
        /// ℓ1 mixing weight α.
        alpha: f64,
    },
}

impl Penalty {
    /// The ℓ1 mixing weight α (1 for the lasso).
    #[inline]
    pub fn alpha(&self) -> f64 {
        match *self {
            Penalty::Lasso => 1.0,
            Penalty::ElasticNet { alpha } => alpha,
        }
    }

    /// ℓ2 weight `(1 − α)` (0 for the lasso).
    #[inline]
    pub fn l2_weight(&self) -> f64 {
        1.0 - self.alpha()
    }

    /// Validate α ∈ (0, 1].
    pub fn validate(&self) -> crate::error::Result<()> {
        let a = self.alpha();
        if a <= 0.0 || a > 1.0 || !a.is_finite() {
            return Err(crate::error::HssrError::Config(format!(
                "elastic net alpha must be in (0, 1], got {a}"
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn penalty_weights() {
        assert_eq!(Penalty::Lasso.alpha(), 1.0);
        assert_eq!(Penalty::Lasso.l2_weight(), 0.0);
        let en = Penalty::ElasticNet { alpha: 0.75 };
        assert_eq!(en.alpha(), 0.75);
        assert!((en.l2_weight() - 0.25).abs() < 1e-15);
    }

    #[test]
    fn penalty_validation() {
        assert!(Penalty::Lasso.validate().is_ok());
        assert!(Penalty::ElasticNet { alpha: 0.5 }.validate().is_ok());
        assert!(Penalty::ElasticNet { alpha: 0.0 }.validate().is_err());
        assert!(Penalty::ElasticNet { alpha: 1.5 }.validate().is_err());
    }
}
