//! Column sources for the inner optimizers: resident design vs pinned
//! store cursor.
//!
//! The inner loops (CD, blockwise GD, weighted CD inside IRLS) only ever
//! need *one column at a time*, walked in ascending working-set order.
//! [`ColAccess`] captures exactly that contract, so the same generic loop
//! body ([`crate::solver::cd::cd_solve_on`], …) runs either on the
//! resident [`DenseMatrix`] ([`DenseCols`], infallible) or directly on a
//! disk-backed [`crate::data::store::ColumnStore`] through a pinned
//! single-chunk cursor ([`StoreCols`]) — the chunk under the cursor is
//! exempt from LRU eviction and swapped as the walk advances, so a full
//! fit completes under a one-chunk cache budget with peak resident bytes
//! ≤ budget.
//!
//! Served values are **bit-identical** across sources: spilled stores
//! hold the exact standardized bytes of the design, so every dot/axpy in
//! the inner loops sees the same numbers in the same order. The only
//! difference is fallibility (disk reads can fail) and accounting (store
//! columns count as `solver_cols`).
//!
//! [`ColSource::for_engine`] picks the source the way the fits do: a
//! store-advertising engine ([`ScanEngine::column_store`]) gets the
//! pinned cursor, every other engine the resident design.

use crate::data::store::{ColumnStore, PinnedColumns};
use crate::error::Result;
use crate::linalg::{ops, DenseMatrix};
use crate::runtime::ScanEngine;

/// One-column-at-a-time access to the standardized design.
pub trait ColAccess {
    /// Rows per column.
    fn nrows(&self) -> usize;

    /// Serve standardized column `j`. `&mut` because a store-backed
    /// source moves its pinned chunk; the dense source never fails.
    fn col(&mut self, j: usize) -> Result<&[f64]>;

    /// Serve columns `a` and `b` simultaneously, when the source can hold
    /// two live column borrows at once. The fused CD cycle uses this to
    /// pipeline the deferred residual update of the previous coordinate
    /// into the correlation pass of the next one
    /// ([`crate::linalg::ops::axpy_dot`] — one residual traversal instead
    /// of two).
    ///
    /// Default: `Ok(None)` — "not supported, fall back to sequential
    /// [`ColAccess::col`] calls". A pinned store cursor must decline: its
    /// two columns may live in different chunks, and only one chunk is
    /// pinned at a time.
    fn col_pair(&mut self, _a: usize, _b: usize) -> Result<Option<(&[f64], &[f64])>> {
        Ok(None)
    }

    /// Whether [`ColAccess::col_pair`] serves pairs — constant per source,
    /// so the CD cycle can pick its loop shape once up front (a source
    /// without pair support must never pay a duplicate column fetch for a
    /// deferred update).
    fn fused_pairs(&self) -> bool {
        false
    }
}

/// Resident columns of a [`DenseMatrix`] — the native/PJRT path.
pub struct DenseCols<'a>(&'a DenseMatrix);

impl<'a> DenseCols<'a> {
    /// Wrap a resident design.
    pub fn new(x: &'a DenseMatrix) -> Self {
        DenseCols(x)
    }
}

impl ColAccess for DenseCols<'_> {
    fn nrows(&self) -> usize {
        self.0.nrows()
    }

    fn col(&mut self, j: usize) -> Result<&[f64]> {
        Ok(self.0.col(j))
    }

    fn col_pair(&mut self, a: usize, b: usize) -> Result<Option<(&[f64], &[f64])>> {
        Ok(Some((self.0.col(a), self.0.col(b))))
    }

    fn fused_pairs(&self) -> bool {
        true
    }
}

/// Store-served columns through a pinned single-chunk cursor — the
/// out-of-core path.
pub struct StoreCols<'a>(PinnedColumns<'a>);

impl<'a> StoreCols<'a> {
    /// Open a pinned cursor on `store`.
    pub fn new(store: &'a ColumnStore) -> Self {
        StoreCols(store.pin_cols())
    }
}

impl ColAccess for StoreCols<'_> {
    fn nrows(&self) -> usize {
        self.0.nrows()
    }

    fn col(&mut self, j: usize) -> Result<&[f64]> {
        self.0.col(j)
    }
}

/// Runtime-selected column source: what the `Problem` impls hand their
/// inner loops.
pub enum ColSource<'a> {
    /// Resident design (infallible).
    Dense(DenseCols<'a>),
    /// Pinned store cursor (diskless fit).
    Store(StoreCols<'a>),
}

impl<'a> ColSource<'a> {
    /// The source matching `engine`: the pinned store cursor when the
    /// engine advertises a column store, else the resident design.
    pub fn for_engine(engine: &'a dyn ScanEngine, x: &'a DenseMatrix) -> ColSource<'a> {
        match engine.column_store() {
            Some(store) => ColSource::Store(StoreCols::new(store)),
            None => ColSource::Dense(DenseCols::new(x)),
        }
    }

    /// Whether this source reads from a store (for tests/reports).
    pub fn is_store(&self) -> bool {
        matches!(self, ColSource::Store(_))
    }
}

impl ColAccess for ColSource<'_> {
    fn nrows(&self) -> usize {
        match self {
            ColSource::Dense(d) => d.nrows(),
            ColSource::Store(s) => ColAccess::nrows(s),
        }
    }

    fn col(&mut self, j: usize) -> Result<&[f64]> {
        match self {
            ColSource::Dense(d) => d.col(j),
            ColSource::Store(s) => s.col(j),
        }
    }

    fn col_pair(&mut self, a: usize, b: usize) -> Result<Option<(&[f64], &[f64])>> {
        match self {
            ColSource::Dense(d) => d.col_pair(a, b),
            ColSource::Store(s) => s.col_pair(a, b),
        }
    }

    fn fused_pairs(&self) -> bool {
        match self {
            ColSource::Dense(d) => d.fused_pairs(),
            ColSource::Store(s) => s.fused_pairs(),
        }
    }
}

/// `X · β` through a column source: ascending sparse axpy over the
/// nonzero coefficients — exactly [`DenseMatrix::matvec`]'s skip-zeros
/// accumulation order, so the result is bit-identical to the dense
/// product (IRLS uses this to refresh `η` without touching the resident
/// design).
pub fn fit_eta<C: ColAccess>(cols: &mut C, beta: &[f64]) -> Result<Vec<f64>> {
    let mut out = vec![0.0; cols.nrows()];
    for (j, &bj) in beta.iter().enumerate() {
        if bj != 0.0 {
            ops::axpy(bj, cols.col(j)?, &mut out);
        }
    }
    Ok(out)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::data::store::write_dataset;
    use crate::data::DataSpec;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("hssr_colsource_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn store_source_matches_dense_bitwise() {
        let ds = DataSpec::gene_like(18, 25).generate(3);
        let path = tmp("colsrc.store");
        write_dataset(&ds, 4, &path).unwrap();
        let store = ColumnStore::open(&path, 4 * 18 * 8).unwrap();
        let mut dense = DenseCols::new(&ds.x);
        let mut disk = StoreCols::new(&store);
        assert_eq!(ColAccess::nrows(&dense), ColAccess::nrows(&disk));
        for j in [0usize, 7, 24, 3] {
            assert_eq!(dense.col(j).unwrap(), disk.col(j).unwrap(), "col {j}");
        }
        drop(disk);

        let mut beta = vec![0.0; 25];
        beta[2] = 0.7;
        beta[11] = -1.3;
        beta[24] = 0.01;
        let want = ds.x.matvec(&beta);
        let got = fit_eta(&mut StoreCols::new(&store), &beta).unwrap();
        assert_eq!(got, want, "store-backed eta refresh drifted");
        // Only the nonzero coefficients' columns were served.
        assert!(store.counters().solver_cols() >= 3);
        assert_eq!(store.counters().cols_fetched(), 0);
    }
}
