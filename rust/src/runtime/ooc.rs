//! The out-of-core scan engine: every screening/KKT scan served from the
//! disk-backed [`ColumnStore`] through its bounded LRU chunk cache.
//!
//! [`OocEngine`] is the third [`ScanEngine`] (`--engine ooc`,
//! [`super::EngineKind::Ooc`]). It keeps the trait's scan-then-filter
//! fused defaults, so every fused pass decomposes into counted
//! [`ColumnStore::scan_subset`] calls — each one a prefetch (pool-parallel
//! chunk reads for the upcoming column set) followed by per-column dots
//! against cached chunks — while selecting **exactly** what the native
//! one-pass kernels select. The paths and the ablation benches therefore
//! report *real* I/O per rule: disk chunk loads, bytes read, cache hits,
//! and peak resident bytes, all bounded by the `HSSR_CACHE_MB` budget.
//!
//! The inner optimizers (CD/GD/IRLS) run **on the store too**: when a fit
//! sees [`ScanEngine::column_store`] return `Some`, it routes coordinate
//! updates through a pinned single-chunk cursor
//! ([`crate::data::store::PinnedColumns`]) instead of resident strong-set
//! columns, so `--engine ooc` fits — not just scans — out-of-core, with
//! peak resident bytes bounded by the cache budget. The engine still
//! receives the design matrix for shape cross-checks (and because spills
//! are created *from* it), but no solver or scan path reads its columns.
//!
//! With prefetch enabled (`--prefetch` / `HSSR_PREFETCH=1`), the engine
//! additionally owns a [`crate::data::store::Prefetcher`]: the driver
//! hands it the next λ's SSR-predicted working set via
//! [`ScanEngine::prefetch_columns`] while the current inner solve runs,
//! hiding chunk-read latency behind compute — measured by the
//! `stalls`/`prefetch_*` counters, never assumed.
//!
//! Setting `HSSR_ENGINE=ooc` reroutes the default-engine `fit_*` shims
//! through a spilled store (see [`env_engine_for`]) — this is how CI runs
//! the whole test suite out-of-core under a tiny cache budget.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::ScanEngine;
use crate::data::store::{self, ColumnStore, Prefetcher};
use crate::error::Result;
use crate::linalg::DenseMatrix;

/// Removes a spill file when dropped. Declared as the *last* field of
/// [`OocEngine`] so the store's file handle is closed first — on
/// platforms where an open file cannot be unlinked (Windows), the
/// deletion then still succeeds.
struct TempSpill(PathBuf);

impl Drop for TempSpill {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

/// A [`ScanEngine`] serving scans — and, via pinned chunk cursors, the
/// inner solvers — from a disk-backed [`ColumnStore`].
pub struct OocEngine {
    /// Shared so the async [`Prefetcher`] thread can read alongside the
    /// fit.
    store: Arc<ColumnStore>,
    /// The λ-ahead prefetch service, when enabled. Declared before
    /// `_cleanup` so its Drop joins the reader thread while the spill
    /// file is still alive.
    prefetcher: Option<Prefetcher>,
    // Field order matters: dropped after `store` releases the handle.
    _cleanup: Option<TempSpill>,
}

impl OocEngine {
    /// Mount an existing store file with an explicit cache budget
    /// (bytes). `HSSR_PREFETCH=1` enables the async prefetcher.
    pub fn open(path: &Path, budget_bytes: usize) -> Result<OocEngine> {
        let engine = OocEngine {
            store: Arc::new(ColumnStore::open(path, budget_bytes)?),
            prefetcher: None,
            _cleanup: None,
        };
        Ok(engine.auto_prefetch())
    }

    /// Wrap an already-open store. `HSSR_PREFETCH=1` enables the async
    /// prefetcher here too.
    pub fn from_store(store: ColumnStore) -> OocEngine {
        OocEngine::from_shared(Arc::new(store))
    }

    /// Wrap a **shared** store handle: the serve-mode path, where many
    /// concurrent fits each mount their own engine over one store — one
    /// chunk cache, one set of counters. `HSSR_PREFETCH=1` enables a
    /// per-engine async prefetcher.
    pub fn from_shared(store: Arc<ColumnStore>) -> OocEngine {
        let engine = OocEngine { store, prefetcher: None, _cleanup: None };
        engine.auto_prefetch()
    }

    /// A clonable handle to the mounted store (serve mode hands these to
    /// per-job engines via [`OocEngine::from_shared`]).
    pub fn shared_store(&self) -> Arc<ColumnStore> {
        Arc::clone(&self.store)
    }

    /// Spawn the λ-ahead prefetch thread (idempotent). The driver feeds
    /// it through [`ScanEngine::prefetch_columns`].
    pub fn enable_prefetch(&mut self) {
        if self.prefetcher.is_none() {
            self.prefetcher = Some(Prefetcher::spawn(Arc::clone(&self.store)));
        }
    }

    /// Whether the async prefetcher is running.
    pub fn prefetch_enabled(&self) -> bool {
        self.prefetcher.is_some()
    }

    fn auto_prefetch(mut self) -> OocEngine {
        if matches!(
            std::env::var("HSSR_PREFETCH").as_deref(),
            Ok("1") | Ok("true") | Ok("on")
        ) {
            self.enable_prefetch();
        }
        self
    }

    /// Spill an in-memory (standardized) design to a fresh store file
    /// under the system temp directory and mount it with the given cache
    /// budget. On unix the file is unlinked right after opening (the open
    /// handle keeps it readable); everywhere the engine's drop removes it
    /// — spills never accumulate.
    pub fn spill(x: &DenseMatrix, y: &[f64], budget_bytes: usize) -> Result<OocEngine> {
        let path = spill_path();
        let p = x.ncols();
        let chunk_cols = store::chunk_cols_for(x.nrows(), p, store::DEFAULT_CHUNK_BYTES);
        let zeros = vec![0.0; p];
        let ones = vec![1.0; p];
        store::write_matrix(x, y, &zeros, &ones, true, chunk_cols, &path)?;
        let mut engine = OocEngine::open(&path, budget_bytes)?;
        #[cfg(unix)]
        let _ = std::fs::remove_file(&path);
        engine._cleanup = Some(TempSpill(path));
        Ok(engine)
    }

    /// The mounted store (counters, budget, shape).
    pub fn store(&self) -> &ColumnStore {
        &self.store
    }
}

fn spill_path() -> PathBuf {
    static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = SPILL_SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir()
        .join(format!("hssr-spill-{}-{seq}.store", std::process::id()))
}

/// `HSSR_ENGINE=ooc` hook for the default-engine `fit_*` shims: spill the
/// design to a temp store and serve every scan from it (tiny budgets via
/// `HSSR_CACHE_MB` force real cache pressure). Returns `None` when the
/// variable is unset or names the native engine.
pub fn env_engine_for(x: &DenseMatrix, y: &[f64]) -> Result<Option<OocEngine>> {
    match std::env::var("HSSR_ENGINE") {
        Ok(v) if v.eq_ignore_ascii_case("ooc") => {
            Ok(Some(OocEngine::spill(x, y, store::cache_budget_bytes())?))
        }
        _ => Ok(None),
    }
}

impl ScanEngine for OocEngine {
    fn name(&self) -> &'static str {
        "ooc"
    }

    fn scan_subset(
        &self,
        x: &DenseMatrix,
        v: &[f64],
        idx: &[usize],
        out: &mut [f64],
    ) -> Result<()> {
        // Columns come from the store; `x` only cross-checks shape. A
        // zero-column `x` is the store-only dummy design (serve/CV fits
        // that never materialize the matrix) and skips the check.
        debug_assert!(
            x.ncols() == 0 || (x.nrows() == self.store.nrows() && x.ncols() == self.store.ncols()),
            "store/design shape mismatch"
        );
        let _ = x;
        self.store.scan_subset(v, idx, out)
    }

    fn scan_all(&self, x: &DenseMatrix, v: &[f64], out: &mut [f64]) -> Result<()> {
        let idx: Vec<usize> = (0..self.store.ncols()).collect();
        self.scan_subset(x, v, &idx, out)
    }

    fn scan_all_f32(&self, x: &DenseMatrix, v: &[f64], out: &mut [f64]) -> Result<bool> {
        debug_assert!(
            x.ncols() == 0 || (x.nrows() == self.store.nrows() && x.ncols() == self.store.ncols()),
            "store/design shape mismatch"
        );
        let _ = x;
        // With a shadow section the f32 columns stream off disk at half
        // the bytes of the exact scan; without one the store casts its
        // served f64 columns — identical f32 bits either way, so the
        // mixed-precision rules behave the same on any store file.
        self.store.scan_all_f32(v, out)?;
        Ok(true)
    }

    fn column_store(&self) -> Option<&ColumnStore> {
        Some(&self.store)
    }

    fn prefetch_columns(&self, cols: &[usize]) {
        if let Some(pf) = &self.prefetcher {
            pf.request(cols);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::store::write_dataset;
    use crate::data::DataSpec;
    use crate::rng::Pcg64;
    use crate::runtime::native::NativeEngine;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("hssr_ooc_engine_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn scans_match_native_bitwise() {
        let ds = DataSpec::gene_like(40, 90).generate(5);
        let path = tmp("scan.store");
        write_dataset(&ds, 16, &path).unwrap();
        let ooc = OocEngine::open(&path, 1 << 20).unwrap();
        let native = NativeEngine::new();
        let mut rng = Pcg64::new(4);
        let v = rng.normal_vec(40);
        let mut a = vec![0.0; 90];
        let mut b = vec![0.0; 90];
        ooc.scan_all(&ds.x, &v, &mut a).unwrap();
        native.scan_all(&ds.x, &v, &mut b).unwrap();
        assert_eq!(a, b, "ooc scan must be bit-identical to native");
        let idx = vec![3usize, 17, 88];
        let mut sa = vec![0.0; 3];
        ooc.scan_subset(&ds.x, &v, &idx, &mut sa).unwrap();
        assert_eq!(sa, vec![b[3], b[17], b[88]]);
        assert_eq!(ooc.store().counters().cols_fetched(), 93);
        assert!(ooc.store().counters().bytes_read() > 0);
    }

    /// `prefetch_columns` hands the set to the background service, which
    /// fills the cache without any demand stall; the engine advertises
    /// its store to the solver layer.
    #[test]
    fn prefetch_columns_feeds_the_background_service() {
        let ds = DataSpec::synthetic(20, 24, 3).generate(11);
        let mut ooc = OocEngine::spill(&ds.x, &ds.y, 1 << 20).unwrap();
        assert!(ooc.column_store().is_some(), "ooc must advertise its store");
        ooc.enable_prefetch();
        assert!(ooc.prefetch_enabled());
        ooc.prefetch_columns(&(0..24).collect::<Vec<_>>());
        // The service is async: wait (bounded) for it to drain the job.
        for _ in 0..400 {
            if ooc.store().counters().prefetch_issued() >= 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(ooc.store().counters().prefetch_issued() >= 1, "prefetcher never ran");
    }

    /// The ooc f32 scan is bit-identical to the native engine's in-memory
    /// f32 mirror — shadowed or not, the f32 columns are the same casts.
    #[test]
    fn f32_scans_match_native_bitwise() {
        let ds = DataSpec::gene_like(40, 90).generate(15);
        let path = tmp("f32scan.store");
        write_dataset(&ds, 16, &path).unwrap();
        crate::data::store::append_f32_shadow(&path).unwrap();
        let ooc = OocEngine::open(&path, 1 << 20).unwrap();
        assert!(ooc.store().has_f32_shadow());
        let native = NativeEngine::new();
        let mut rng = Pcg64::new(8);
        let v = rng.normal_vec(40);
        let mut a = vec![0.0; 90];
        let mut b = vec![0.0; 90];
        assert!(ooc.scan_all_f32(&ds.x, &v, &mut a).unwrap());
        assert!(native.scan_all_f32(&ds.x, &v, &mut b).unwrap());
        assert_eq!(a, b, "ooc f32 scan must be bit-identical to native");
    }

    #[test]
    fn spill_serves_the_same_values() {
        let ds = DataSpec::synthetic(30, 25, 3).generate(9);
        let ooc = OocEngine::spill(&ds.x, &ds.y, 1 << 20).unwrap();
        assert_eq!(ooc.store().nrows(), 30);
        assert_eq!(ooc.store().ncols(), 25);
        let v = vec![0.5; 30];
        let mut a = vec![0.0; 25];
        ooc.scan_all(&ds.x, &v, &mut a).unwrap();
        let want = crate::linalg::blocked::scan_all_vec(&ds.x, &v);
        assert_eq!(a, want);
    }
}
