//! The default pure-Rust scan engine, backed by the persistent worker pool.

use super::ScanEngine;
use crate::error::Result;
use crate::linalg::blocked::{self, FusedKktOut, FusedScreenOut};
use crate::linalg::DenseMatrix;

/// Blocked Rust kernels dispatched on [`crate::linalg::pool`] (see
/// [`crate::linalg::blocked`]). One process-wide pool is created lazily and
/// shared by every engine instance, so a fit never spawns per-scan threads.
/// Overrides every fused [`ScanEngine`] entry point with the true
/// single-traversal kernels.
#[derive(Debug, Default)]
pub struct NativeEngine;

impl NativeEngine {
    /// Create the engine (stateless; the pool is process-global).
    pub fn new() -> Self {
        NativeEngine
    }
}

// The fused entry points mirror the trait's (wide) signatures.
#[allow(clippy::too_many_arguments)]
impl ScanEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn scan_subset(
        &self,
        x: &DenseMatrix,
        v: &[f64],
        idx: &[usize],
        out: &mut [f64],
    ) -> Result<()> {
        blocked::scan_subset(x, v, idx, out);
        Ok(())
    }

    fn scan_all(&self, x: &DenseMatrix, v: &[f64], out: &mut [f64]) -> Result<()> {
        blocked::scan_all(x, v, out);
        Ok(())
    }

    fn fused_screen(
        &self,
        x: &DenseMatrix,
        r: &[f64],
        keep: Option<&(dyn Fn(usize) -> bool + Sync)>,
        ssr_threshold: f64,
        survive: &mut [bool],
        z: &mut [f64],
        z_valid: &mut [bool],
    ) -> Result<FusedScreenOut> {
        Ok(blocked::fused_screen(x, r, keep, ssr_threshold, survive, z, z_valid))
    }

    fn fused_kkt(
        &self,
        x: &DenseMatrix,
        r: &[f64],
        survive: &[bool],
        in_strong: &[bool],
        violates: &(dyn Fn(f64) -> bool + Sync),
        refresh_strong: bool,
        z: &mut [f64],
        z_valid: &mut [bool],
    ) -> Result<FusedKktOut> {
        Ok(blocked::fused_kkt(
            x,
            r,
            survive,
            in_strong,
            violates,
            refresh_strong,
            z,
            z_valid,
        ))
    }

    fn group_norms(
        &self,
        x: &DenseMatrix,
        r: &[f64],
        starts: &[usize],
        sizes: &[usize],
        groups: &[usize],
        znorm: &mut [f64],
        znorm_valid: &mut [bool],
    ) -> Result<u64> {
        Ok(blocked::group_norms(x, r, starts, sizes, groups, znorm, znorm_valid))
    }

    fn fused_group_screen(
        &self,
        x: &DenseMatrix,
        r: &[f64],
        starts: &[usize],
        sizes: &[usize],
        keep: Option<&(dyn Fn(usize) -> bool + Sync)>,
        ssr_t: f64,
        survive: &mut [bool],
        znorm: &mut [f64],
        znorm_valid: &mut [bool],
    ) -> Result<FusedScreenOut> {
        Ok(blocked::fused_group_screen(
            x,
            r,
            starts,
            sizes,
            keep,
            ssr_t,
            survive,
            znorm,
            znorm_valid,
        ))
    }

    fn fused_group_kkt(
        &self,
        x: &DenseMatrix,
        r: &[f64],
        starts: &[usize],
        sizes: &[usize],
        survive: &[bool],
        in_strong: &[bool],
        violates: &(dyn Fn(usize, f64) -> bool + Sync),
        refresh_strong: bool,
        znorm: &mut [f64],
        znorm_valid: &mut [bool],
    ) -> Result<FusedKktOut> {
        Ok(blocked::fused_group_kkt(
            x,
            r,
            starts,
            sizes,
            survive,
            in_strong,
            violates,
            refresh_strong,
            znorm,
            znorm_valid,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn matches_blocked_kernels() {
        let mut rng = Pcg64::new(1);
        let x = DenseMatrix::from_fn(30, 12, |_, _| rng.normal());
        let v = rng.normal_vec(30);
        let e = NativeEngine::new();
        let mut a = vec![0.0; 12];
        e.scan_all(&x, &v, &mut a).unwrap();
        assert_eq!(a, blocked::scan_all_vec(&x, &v));
        let idx = vec![2usize, 9];
        let mut b = vec![0.0; 2];
        e.scan_subset(&x, &v, &idx, &mut b).unwrap();
        assert_eq!(b, vec![a[2], a[9]]);
    }
}
