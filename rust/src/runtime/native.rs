//! The default pure-Rust scan engine, backed by the persistent worker pool.

use std::sync::{Mutex, PoisonError};

use super::ScanEngine;
use crate::error::Result;
use crate::linalg::blocked::{self, FusedKktOut, FusedScreenOut};
use crate::linalg::DenseMatrix;

/// Blocked Rust kernels dispatched on [`crate::linalg::pool`] (see
/// [`crate::linalg::blocked`]). One process-wide pool is created lazily and
/// shared by every engine instance, so a fit never spawns per-scan threads.
/// Overrides every fused [`ScanEngine`] entry point with the true
/// single-traversal kernels.
#[derive(Debug, Default)]
pub struct NativeEngine {
    /// Lazily built in-memory f32 shadow of the standardized design for
    /// [`ScanEngine::scan_all_f32`]: `(col0 pointer, n, p, column-major
    /// f32 copy)`. Keyed by allocation identity + shape, and re-verified
    /// against the design on every use (first entry of each column), so a
    /// different matrix — even one reusing the same allocation — rebuilds
    /// it rather than serving stale values.
    mirror: Mutex<Option<(usize, usize, usize, Vec<f32>)>>,
}

impl NativeEngine {
    /// Create the engine (the pool is process-global; the only per-engine
    /// state is the lazily built f32 mirror).
    pub fn new() -> Self {
        NativeEngine::default()
    }
}

// The fused entry points mirror the trait's (wide) signatures.
#[allow(clippy::too_many_arguments)]
impl ScanEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn scan_subset(
        &self,
        x: &DenseMatrix,
        v: &[f64],
        idx: &[usize],
        out: &mut [f64],
    ) -> Result<()> {
        blocked::scan_subset(x, v, idx, out);
        Ok(())
    }

    fn scan_all(&self, x: &DenseMatrix, v: &[f64], out: &mut [f64]) -> Result<()> {
        blocked::scan_all(x, v, out);
        Ok(())
    }

    fn scan_all_f32(&self, x: &DenseMatrix, v: &[f64], out: &mut [f64]) -> Result<bool> {
        let n = x.nrows();
        let p = x.ncols();
        if n == 0 || p == 0 {
            return Ok(false);
        }
        let key = (x.col(0).as_ptr() as usize, n, p);
        let mut guard = self.mirror.lock().unwrap_or_else(PoisonError::into_inner);
        let fresh = match guard.as_ref() {
            Some((ptr, mn, mp, m)) => {
                (*ptr, *mn, *mp) == key
                    && (0..p).all(|j| m[j * n] == x.col(j)[0] as f32)
            }
            None => false,
        };
        if !fresh {
            let mut m = Vec::with_capacity(n * p);
            for j in 0..p {
                m.extend(x.col(j).iter().map(|&e| e as f32));
            }
            *guard = Some((key.0, n, p, m));
        }
        let Some((_, _, _, mirror)) = guard.as_ref() else {
            return Ok(false);
        };
        let v32: Vec<f32> = v.iter().map(|&e| e as f32).collect();
        blocked::scan_all_f32_mirror(mirror, n, p, &v32, out);
        Ok(true)
    }

    fn fused_screen(
        &self,
        x: &DenseMatrix,
        r: &[f64],
        keep: Option<&(dyn Fn(usize) -> bool + Sync)>,
        ssr_threshold: f64,
        survive: &mut [bool],
        z: &mut [f64],
        z_valid: &mut [bool],
    ) -> Result<FusedScreenOut> {
        Ok(blocked::fused_screen(x, r, keep, ssr_threshold, survive, z, z_valid))
    }

    fn fused_kkt(
        &self,
        x: &DenseMatrix,
        r: &[f64],
        survive: &[bool],
        in_strong: &[bool],
        violates: &(dyn Fn(f64) -> bool + Sync),
        refresh_strong: bool,
        z: &mut [f64],
        z_valid: &mut [bool],
    ) -> Result<FusedKktOut> {
        Ok(blocked::fused_kkt(
            x,
            r,
            survive,
            in_strong,
            violates,
            refresh_strong,
            z,
            z_valid,
        ))
    }

    fn group_norms(
        &self,
        x: &DenseMatrix,
        r: &[f64],
        starts: &[usize],
        sizes: &[usize],
        groups: &[usize],
        znorm: &mut [f64],
        znorm_valid: &mut [bool],
    ) -> Result<u64> {
        Ok(blocked::group_norms(x, r, starts, sizes, groups, znorm, znorm_valid))
    }

    fn fused_group_screen(
        &self,
        x: &DenseMatrix,
        r: &[f64],
        starts: &[usize],
        sizes: &[usize],
        keep: Option<&(dyn Fn(usize) -> bool + Sync)>,
        ssr_t: f64,
        survive: &mut [bool],
        znorm: &mut [f64],
        znorm_valid: &mut [bool],
    ) -> Result<FusedScreenOut> {
        Ok(blocked::fused_group_screen(
            x,
            r,
            starts,
            sizes,
            keep,
            ssr_t,
            survive,
            znorm,
            znorm_valid,
        ))
    }

    fn fused_group_kkt(
        &self,
        x: &DenseMatrix,
        r: &[f64],
        starts: &[usize],
        sizes: &[usize],
        survive: &[bool],
        in_strong: &[bool],
        violates: &(dyn Fn(usize, f64) -> bool + Sync),
        refresh_strong: bool,
        znorm: &mut [f64],
        znorm_valid: &mut [bool],
    ) -> Result<FusedKktOut> {
        Ok(blocked::fused_group_kkt(
            x,
            r,
            starts,
            sizes,
            survive,
            in_strong,
            violates,
            refresh_strong,
            znorm,
            znorm_valid,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn matches_blocked_kernels() {
        let mut rng = Pcg64::new(1);
        let x = DenseMatrix::from_fn(30, 12, |_, _| rng.normal());
        let v = rng.normal_vec(30);
        let e = NativeEngine::new();
        let mut a = vec![0.0; 12];
        e.scan_all(&x, &v, &mut a).unwrap();
        assert_eq!(a, blocked::scan_all_vec(&x, &v));
        let idx = vec![2usize, 9];
        let mut b = vec![0.0; 2];
        e.scan_subset(&x, &v, &idx, &mut b).unwrap();
        assert_eq!(b, vec![a[2], a[9]]);
    }
}
