//! The default pure-Rust scan engine.

use super::ScanEngine;
use crate::error::Result;
use crate::linalg::{blocked, DenseMatrix};

/// Blocked, multi-threaded Rust kernels (see [`crate::linalg::blocked`]).
#[derive(Debug, Default)]
pub struct NativeEngine;

impl NativeEngine {
    /// Create the engine (stateless).
    pub fn new() -> Self {
        NativeEngine
    }
}

impl ScanEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn scan_subset(
        &self,
        x: &DenseMatrix,
        v: &[f64],
        idx: &[usize],
        out: &mut [f64],
    ) -> Result<()> {
        blocked::scan_subset(x, v, idx, out);
        Ok(())
    }

    fn scan_all(&self, x: &DenseMatrix, v: &[f64], out: &mut [f64]) -> Result<()> {
        blocked::scan_all(x, v, out);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn matches_blocked_kernels() {
        let mut rng = Pcg64::new(1);
        let x = DenseMatrix::from_fn(30, 12, |_, _| rng.normal());
        let v = rng.normal_vec(30);
        let e = NativeEngine::new();
        let mut a = vec![0.0; 12];
        e.scan_all(&x, &v, &mut a).unwrap();
        assert_eq!(a, blocked::scan_all_vec(&x, &v));
        let idx = vec![2usize, 9];
        let mut b = vec![0.0; 2];
        e.scan_subset(&x, &v, &idx, &mut b).unwrap();
        assert_eq!(b, vec![a[2], a[9]]);
    }
}
