//! Execution engines for the screening scan `z = Xᵀr/n` — the hot compute
//! of every rule and of KKT checking.
//!
//! Two interchangeable engines implement [`ScanEngine`]:
//!
//! * [`native::NativeEngine`] — blocked, multi-threaded pure-Rust kernels
//!   (the default; fastest on CPU-sized problems).
//! * [`pjrt::PjrtEngine`] — loads the AOT artifacts produced by
//!   `make artifacts` (JAX/Pallas → HLO text) and executes them through the
//!   PJRT C API via the `xla` crate. This is the L1/L2/L3 composition path:
//!   the same kernel validated against the pure-jnp oracle in
//!   `python/tests` runs inside the Rust coordinator with *no Python at
//!   runtime*.
//!
//! The PJRT engine is tile-based: artifacts are compiled for a fixed
//! `(N_TILE × P_TILE)` block (AOT requires static shapes); arbitrary
//! matrices are covered by padding the edge tiles. See
//! `python/compile/aot.py` for the tile shapes emitted.

pub mod native;
pub mod pjrt;

use crate::error::Result;
use crate::linalg::DenseMatrix;

/// A provider of the screening scan.
///
/// Not `Send`/`Sync`: the PJRT client wraps raw C-API handles without
/// thread-safety markers. Multi-threaded callers (the job runner) create
/// one engine per worker thread.
pub trait ScanEngine {
    /// Engine name for reports.
    fn name(&self) -> &'static str;

    /// `out[k] = x_{idx[k]}ᵀ v / n` over a subset of columns.
    fn scan_subset(
        &self,
        x: &DenseMatrix,
        v: &[f64],
        idx: &[usize],
        out: &mut [f64],
    ) -> Result<()>;

    /// `out[j] = x_jᵀ v / n` over all columns.
    fn scan_all(&self, x: &DenseMatrix, v: &[f64], out: &mut [f64]) -> Result<()>;
}

/// Engine selector used by configs and the CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure-Rust blocked kernels.
    Native,
    /// AOT JAX/Pallas artifacts through PJRT.
    Pjrt,
}

impl EngineKind {
    /// Parse from a CLI/config string.
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Some(EngineKind::Native),
            "pjrt" | "xla" => Some(EngineKind::Pjrt),
            _ => None,
        }
    }
}

/// Build an engine. For [`EngineKind::Pjrt`], `artifact_dir` must contain
/// the HLO artifacts (default `artifacts/`).
pub fn make_engine(kind: EngineKind, artifact_dir: &str) -> Result<Box<dyn ScanEngine>> {
    match kind {
        EngineKind::Native => Ok(Box::new(native::NativeEngine::new())),
        EngineKind::Pjrt => Ok(Box::new(pjrt::PjrtEngine::load(artifact_dir)?)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_parsing() {
        assert_eq!(EngineKind::parse("native"), Some(EngineKind::Native));
        assert_eq!(EngineKind::parse("PJRT"), Some(EngineKind::Pjrt));
        assert_eq!(EngineKind::parse("xla"), Some(EngineKind::Pjrt));
        assert_eq!(EngineKind::parse("gpu"), None);
    }
}
