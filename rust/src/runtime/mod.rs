//! Execution engines for the screening scan `z = Xᵀr/n` — the hot compute
//! of every rule and of KKT checking.
//!
//! Three interchangeable engines implement [`ScanEngine`]:
//!
//! * [`native::NativeEngine`] — blocked pure-Rust kernels dispatched on the
//!   persistent [`crate::linalg::pool`] worker pool (the default; fastest
//!   on CPU-sized problems). It overrides the **fused** entry points with
//!   true single-pass kernels.
//! * [`pjrt::PjrtEngine`] — loads the AOT artifacts produced by
//!   `make artifacts` (JAX/Pallas → HLO text) and executes them through the
//!   PJRT C API via the `xla` crate (behind the `pjrt` cargo feature; a
//!   stub that reports itself unavailable is compiled otherwise). This is
//!   the L1/L2/L3 composition path: the same kernel validated against the
//!   pure-jnp oracle in `python/tests` runs inside the Rust coordinator
//!   with *no Python at runtime*.
//! * [`ooc::OocEngine`] — out-of-core: scans served from the disk-backed
//!   [`crate::data::store::ColumnStore`] through a bounded LRU chunk cache
//!   (`--engine ooc`, `HSSR_CACHE_MB`), reporting real I/O per rule. It
//!   keeps the scan-then-filter fused defaults so every column read is a
//!   counted store fetch, with selections bit-identical to native.
//!
//! ## Fused entry points
//!
//! Algorithm 1 touches the same column set up to three times per λ step:
//! safe-rule screen, SSR filter, and post-convergence KKT check. The trait
//! therefore exposes *fused* passes — [`ScanEngine::fused_screen`],
//! [`ScanEngine::fused_kkt`], and their group-lasso analogues — that
//! compute each `z_j` once and immediately apply every predicate. The
//! trait provides **scan-then-filter default implementations** built on
//! [`ScanEngine::scan_subset`], so engines that can only execute plain
//! scans (the tile-based PJRT engine) fall back transparently;
//! `NativeEngine` overrides them with the one-traversal kernels in
//! [`crate::linalg::blocked`].
//!
//! The PJRT engine is tile-based: artifacts are compiled for a fixed
//! `(N_TILE × P_TILE)` block (AOT requires static shapes); arbitrary
//! matrices are covered by padding the edge tiles. See
//! `python/compile/aot.py` for the tile shapes emitted.

pub mod native;
pub mod ooc;
pub mod pjrt;

use crate::error::Result;
use crate::linalg::blocked::{FusedKktOut, FusedScreenOut};
use crate::linalg::DenseMatrix;

/// A provider of the screening scan.
///
/// Not `Send`/`Sync`: the PJRT client wraps raw C-API handles without
/// thread-safety markers. Multi-threaded callers (the job runner) create
/// one engine per worker thread.
pub trait ScanEngine {
    /// Engine name for reports.
    fn name(&self) -> &'static str;

    /// `out[k] = x_{idx[k]}ᵀ v / n` over a subset of columns.
    fn scan_subset(
        &self,
        x: &DenseMatrix,
        v: &[f64],
        idx: &[usize],
        out: &mut [f64],
    ) -> Result<()>;

    /// `out[j] = x_jᵀ v / n` over all columns.
    fn scan_all(&self, x: &DenseMatrix, v: &[f64], out: &mut [f64]) -> Result<()>;

    /// Reduced-precision screening scan: `out[j] = fl32(x32_jᵀ v32) / n`
    /// over all columns, served from an f32 shadow of the standardized
    /// design (an in-memory mirror for the native engine, the store-side
    /// f32 chunk shadow for ooc). Returns `Ok(false)` — leaving `out`
    /// untouched — when the engine has no shadow; the screening rules then
    /// fall back to the exact f64 scan. Only *screening prefilters* may
    /// consume this: every value it feeds a discard decision must be
    /// widened by [`crate::linalg::simd::f32_scan_error_bound`], and KKT
    /// checks never use it.
    fn scan_all_f32(&self, _x: &DenseMatrix, _v: &[f64], _out: &mut [f64]) -> Result<bool> {
        Ok(false)
    }

    /// The disk-backed column store this engine serves scans from, if
    /// any. A `Some` return is the signal for the inner optimizers to run
    /// store-backed (pinned chunk cursors instead of resident columns) —
    /// see [`crate::solver::columns::ColSource`]. Default: `None` (the
    /// engine computes on the resident design).
    fn column_store(&self) -> Option<&crate::data::store::ColumnStore> {
        None
    }

    /// Hint that `cols` will be wanted soon (the next λ's SSR-predicted
    /// working set): a store-backed engine with an async prefetcher hands
    /// the set to its background thread. Default: no-op — prefetch is an
    /// overlap optimization, never a correctness requirement.
    fn prefetch_columns(&self, _cols: &[usize]) {}

    /// Fused screening pass at one λ step: apply the point-wise safe
    /// predicate `keep` (when given), lazily refresh stale `z_j`, and
    /// classify survivors against the SSR threshold — see
    /// [`crate::linalg::blocked::fused_screen`] for the exact semantics.
    ///
    /// Default: scan-then-filter over [`ScanEngine::scan_subset`] (three
    /// separate passes, same selection — the PJRT fallback).
    #[allow(clippy::too_many_arguments)]
    fn fused_screen(
        &self,
        x: &DenseMatrix,
        r: &[f64],
        keep: Option<&(dyn Fn(usize) -> bool + Sync)>,
        ssr_threshold: f64,
        survive: &mut [bool],
        z: &mut [f64],
        z_valid: &mut [bool],
    ) -> Result<FusedScreenOut> {
        // `p` comes from the state slices, not `x` — a store-backed fit
        // passes a zero-column dummy design (the store has the columns).
        let p = survive.len();
        let mut out = FusedScreenOut::default();
        if let Some(pred) = keep {
            for j in 0..p {
                if survive[j] && !pred(j) {
                    survive[j] = false;
                    out.discarded += 1;
                }
            }
        }
        let stale: Vec<usize> = (0..p).filter(|&j| survive[j] && !z_valid[j]).collect();
        if !stale.is_empty() {
            let mut buf = vec![0.0; stale.len()];
            self.scan_subset(x, r, &stale, &mut buf)?;
            for (s, &j) in stale.iter().enumerate() {
                z[j] = buf[s];
                z_valid[j] = true;
            }
            out.cols_scanned = stale.len() as u64;
        }
        for j in 0..p {
            if survive[j] {
                out.safe_size += 1;
                if z[j].abs() >= ssr_threshold {
                    out.strong.push(j);
                }
            }
        }
        Ok(out)
    }

    /// Fused post-convergence KKT pass: recompute `z_j` for surviving
    /// candidates (and, when `refresh_strong`, for strong columns too) and
    /// collect violators — see [`crate::linalg::blocked::fused_kkt`].
    ///
    /// Columns whose `z_valid[j]` is already set reuse the cached `z[j]`
    /// instead of rescanning (the fused-epoch contract: a dynamic rule's
    /// rescreen may publish correlations computed at the same residual).
    ///
    /// Default: scan-then-filter over [`ScanEngine::scan_subset`].
    #[allow(clippy::too_many_arguments)]
    fn fused_kkt(
        &self,
        x: &DenseMatrix,
        r: &[f64],
        survive: &[bool],
        in_strong: &[bool],
        violates: &(dyn Fn(f64) -> bool + Sync),
        refresh_strong: bool,
        z: &mut [f64],
        z_valid: &mut [bool],
    ) -> Result<FusedKktOut> {
        let p = survive.len();
        let mut out = FusedKktOut::default();
        let check: Vec<usize> = (0..p).filter(|&j| survive[j] && !in_strong[j]).collect();
        if !check.is_empty() {
            let stale: Vec<usize> = check.iter().copied().filter(|&j| !z_valid[j]).collect();
            if !stale.is_empty() {
                let mut buf = vec![0.0; stale.len()];
                self.scan_subset(x, r, &stale, &mut buf)?;
                for (s, &j) in stale.iter().enumerate() {
                    z[j] = buf[s];
                    z_valid[j] = true;
                }
                out.cols_scanned += stale.len() as u64;
            }
            for &j in &check {
                if violates(z[j]) {
                    out.violations.push(j);
                }
            }
            out.checked = check.len();
        }
        if refresh_strong {
            let strong: Vec<usize> = (0..p)
                .filter(|&j| survive[j] && in_strong[j] && !z_valid[j])
                .collect();
            if !strong.is_empty() {
                let mut buf = vec![0.0; strong.len()];
                self.scan_subset(x, r, &strong, &mut buf)?;
                for (s, &j) in strong.iter().enumerate() {
                    z[j] = buf[s];
                    z_valid[j] = true;
                }
                out.cols_scanned += strong.len() as u64;
            }
        }
        Ok(out)
    }

    /// Refresh `znorm[g] = ‖X_gᵀ r‖ / n` for each `g` in `groups`, marking
    /// them valid. Returns columns scanned.
    ///
    /// Default: one [`ScanEngine::scan_subset`] per group (the PJRT
    /// fallback, and exactly the unfused group path's access pattern).
    #[allow(clippy::too_many_arguments)]
    fn group_norms(
        &self,
        x: &DenseMatrix,
        r: &[f64],
        starts: &[usize],
        sizes: &[usize],
        groups: &[usize],
        znorm: &mut [f64],
        znorm_valid: &mut [bool],
    ) -> Result<u64> {
        let mut cols = 0u64;
        for &g in groups {
            let idx: Vec<usize> = (starts[g]..starts[g] + sizes[g]).collect();
            let mut buf = vec![0.0; idx.len()];
            self.scan_subset(x, r, &idx, &mut buf)?;
            znorm[g] = crate::linalg::ops::nrm2(&buf);
            znorm_valid[g] = true;
            cols += idx.len() as u64;
        }
        Ok(cols)
    }

    /// Fused group-level screening pass at one λ step — the group analogue
    /// of [`ScanEngine::fused_screen`]: apply the point-wise group safe
    /// predicate `keep` (when given, from `SafeRule::plan`), lazily refresh
    /// stale `znorm[g] = ‖X_gᵀr‖/n` over the survivors, and classify them
    /// against the group-SSR threshold `√W_g · ssr_t` (rule (20); `ssr_t`
    /// carries the elastic-net α).
    ///
    /// Default: predicate-then-refresh-then-filter over
    /// [`ScanEngine::group_norms`] — three separate sweeps, used by the
    /// scan-counting engines (PJRT, `ChunkedScanEngine`) so every column
    /// read stays an accounted `scan_subset`. `NativeEngine` overrides this
    /// with the true single-traversal kernel
    /// [`crate::linalg::blocked::fused_group_screen`]. Selections are
    /// bit-identical either way (same per-group norm kernel, same
    /// comparisons in the same order as the unfused
    /// screen → norm-refresh → `ssr::group_strong_set` sequence).
    #[allow(clippy::too_many_arguments)]
    fn fused_group_screen(
        &self,
        x: &DenseMatrix,
        r: &[f64],
        starts: &[usize],
        sizes: &[usize],
        keep: Option<&(dyn Fn(usize) -> bool + Sync)>,
        ssr_t: f64,
        survive: &mut [bool],
        znorm: &mut [f64],
        znorm_valid: &mut [bool],
    ) -> Result<FusedScreenOut> {
        let g_count = starts.len();
        let mut out = FusedScreenOut::default();
        if let Some(pred) = keep {
            for g in 0..g_count {
                if survive[g] && !pred(g) {
                    survive[g] = false;
                    out.discarded += 1;
                }
            }
        }
        let stale: Vec<usize> =
            (0..g_count).filter(|&g| survive[g] && !znorm_valid[g]).collect();
        if !stale.is_empty() {
            out.cols_scanned =
                self.group_norms(x, r, starts, sizes, &stale, znorm, znorm_valid)?;
        }
        for g in 0..g_count {
            if survive[g] {
                out.safe_size += 1;
                if znorm[g] >= (sizes[g] as f64).sqrt() * ssr_t {
                    out.strong.push(g);
                }
            }
        }
        Ok(out)
    }

    /// Fused group-level KKT pass — see
    /// [`crate::linalg::blocked::fused_group_kkt`].
    ///
    /// Default: per-group scan-then-filter over
    /// [`ScanEngine::group_norms`].
    #[allow(clippy::too_many_arguments)]
    fn fused_group_kkt(
        &self,
        x: &DenseMatrix,
        r: &[f64],
        starts: &[usize],
        sizes: &[usize],
        survive: &[bool],
        in_strong: &[bool],
        violates: &(dyn Fn(usize, f64) -> bool + Sync),
        refresh_strong: bool,
        znorm: &mut [f64],
        znorm_valid: &mut [bool],
    ) -> Result<FusedKktOut> {
        let g_count = starts.len();
        let mut out = FusedKktOut::default();
        let check: Vec<usize> =
            (0..g_count).filter(|&g| survive[g] && !in_strong[g]).collect();
        out.cols_scanned +=
            self.group_norms(x, r, starts, sizes, &check, znorm, znorm_valid)?;
        for &g in &check {
            out.checked += 1;
            if violates(g, znorm[g]) {
                out.violations.push(g);
            }
        }
        if refresh_strong {
            let strong: Vec<usize> =
                (0..g_count).filter(|&g| survive[g] && in_strong[g]).collect();
            out.cols_scanned +=
                self.group_norms(x, r, starts, sizes, &strong, znorm, znorm_valid)?;
        }
        Ok(out)
    }
}

/// Arithmetic precision of the screening scan (`HSSR_PRECISION`,
/// `--precision`).
///
/// The solvers and KKT checks always run in f64; [`Precision::F32`] only
/// routes the *screening rules'* full scans through the engine's f32
/// shadow ([`ScanEngine::scan_all_f32`]), with every discard bound
/// widened by the computed accumulation error so the surviving sets — and
/// therefore the fitted coefficients — stay bit-identical to the all-f64
/// path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// Exact f64 scans everywhere (the default).
    #[default]
    F64,
    /// f32 shadow scans for the screening prefilters.
    F32,
}

impl Precision {
    /// Parse from a CLI/config string.
    pub fn parse(s: &str) -> Option<Precision> {
        match s.to_ascii_lowercase().as_str() {
            "f64" | "double" => Some(Precision::F64),
            "f32" | "single" | "mixed" => Some(Precision::F32),
            _ => None,
        }
    }

    /// The `HSSR_PRECISION` environment default (f64 when unset or
    /// unrecognized).
    pub fn from_env() -> Precision {
        std::env::var("HSSR_PRECISION")
            .ok()
            .and_then(|s| Precision::parse(&s))
            .unwrap_or_default()
    }

    /// Display label for reports and benches.
    pub fn label(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }
}

/// Engine selector used by configs and the CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure-Rust blocked kernels on the persistent worker pool.
    Native,
    /// AOT JAX/Pallas artifacts through PJRT.
    Pjrt,
    /// Out-of-core: scans served from a disk-backed column store through
    /// a bounded LRU chunk cache ([`ooc::OocEngine`], `HSSR_CACHE_MB`).
    Ooc,
}

impl EngineKind {
    /// Parse from a CLI/config string.
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Some(EngineKind::Native),
            "pjrt" | "xla" => Some(EngineKind::Pjrt),
            "ooc" | "store" => Some(EngineKind::Ooc),
            _ => None,
        }
    }
}

/// Build an engine. For [`EngineKind::Pjrt`], `artifact_dir` must contain
/// the HLO artifacts (default `artifacts/`) and the crate must be built
/// with the `pjrt` feature. [`EngineKind::Ooc`] cannot be built here —
/// an out-of-core engine is mounted *on data* ([`ooc::OocEngine::open`] on
/// a converted store, or [`ooc::OocEngine::spill`] for an in-memory
/// design); the CLI wires this per command.
pub fn make_engine(kind: EngineKind, artifact_dir: &str) -> Result<Box<dyn ScanEngine>> {
    match kind {
        EngineKind::Native => Ok(Box::new(native::NativeEngine::new())),
        EngineKind::Pjrt => Ok(Box::new(pjrt::PjrtEngine::load(artifact_dir)?)),
        EngineKind::Ooc => Err(crate::error::HssrError::Config(
            "the ooc engine is mounted on a store, not built standalone — \
             use OocEngine::open/spill (the CLI does this for --engine ooc)"
                .into(),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_parsing() {
        assert_eq!(EngineKind::parse("native"), Some(EngineKind::Native));
        assert_eq!(EngineKind::parse("PJRT"), Some(EngineKind::Pjrt));
        assert_eq!(EngineKind::parse("xla"), Some(EngineKind::Pjrt));
        assert_eq!(EngineKind::parse("ooc"), Some(EngineKind::Ooc));
        assert_eq!(EngineKind::parse("STORE"), Some(EngineKind::Ooc));
        assert_eq!(EngineKind::parse("gpu"), None);
        assert!(make_engine(EngineKind::Ooc, "artifacts").is_err());
    }

    /// The default (scan-then-filter) fused implementations must select
    /// exactly what the native one-pass kernels select.
    #[test]
    fn default_fused_impls_match_native_overrides() {
        use crate::rng::Pcg64;

        /// Wrapper that deliberately keeps the trait's default fused
        /// implementations (the PJRT fallback path).
        struct ScanOnly(native::NativeEngine);
        impl ScanEngine for ScanOnly {
            fn name(&self) -> &'static str {
                "scan-only"
            }
            fn scan_subset(
                &self,
                x: &DenseMatrix,
                v: &[f64],
                idx: &[usize],
                out: &mut [f64],
            ) -> Result<()> {
                self.0.scan_subset(x, v, idx, out)
            }
            fn scan_all(&self, x: &DenseMatrix, v: &[f64], out: &mut [f64]) -> Result<()> {
                self.0.scan_all(x, v, out)
            }
        }

        let mut rng = Pcg64::new(9);
        let x = DenseMatrix::from_fn(40, 90, |_, _| rng.normal());
        let r = rng.normal_vec(40);
        let fallback = ScanOnly(native::NativeEngine::new());
        let nat = native::NativeEngine::new();
        let pred = |j: usize| j % 6 != 2;
        let keep: &(dyn Fn(usize) -> bool + Sync) = &pred;

        let mut s1 = vec![true; 90];
        let mut z1 = vec![0.0; 90];
        let mut v1 = vec![false; 90];
        let a = fallback
            .fused_screen(&x, &r, Some(keep), 0.02, &mut s1, &mut z1, &mut v1)
            .unwrap();
        let mut s2 = vec![true; 90];
        let mut z2 = vec![0.0; 90];
        let mut v2 = vec![false; 90];
        let b = nat
            .fused_screen(&x, &r, Some(keep), 0.02, &mut s2, &mut z2, &mut v2)
            .unwrap();
        assert_eq!(a.strong, b.strong);
        assert_eq!(a.safe_size, b.safe_size);
        assert_eq!(a.discarded, b.discarded);
        assert_eq!(s1, s2);
        assert_eq!(z1, z2);

        let in_strong: Vec<bool> = (0..90).map(|j| j % 4 == 0).collect();
        let viol = |zj: f64| zj.abs() > 0.04;
        let mut za = z1.clone();
        let mut va = vec![false; 90];
        let ka = fallback
            .fused_kkt(&x, &r, &s1, &in_strong, &viol, true, &mut za, &mut va)
            .unwrap();
        let mut zb = z2.clone();
        let mut vb = vec![false; 90];
        let kb = nat
            .fused_kkt(&x, &r, &s2, &in_strong, &viol, true, &mut zb, &mut vb)
            .unwrap();
        assert_eq!(ka.violations, kb.violations);
        assert_eq!(ka.checked, kb.checked);
        assert_eq!(za, zb);

        // Group screen: the scan-then-filter default must select exactly
        // what the native one-traversal kernel selects, with identical
        // norms and scan accounting.
        let sizes = vec![3usize, 4, 2, 5, 3, 4, 2, 4];
        let starts: Vec<usize> = sizes
            .iter()
            .scan(0usize, |acc, &s| {
                let st = *acc;
                *acc += s;
                Some(st)
            })
            .collect();
        let g_count = sizes.len();
        let gpred = |g: usize| g != 3;
        let gkeep: &(dyn Fn(usize) -> bool + Sync) = &gpred;
        let mut gs1 = vec![true; g_count];
        let mut gz1 = vec![0.0; g_count];
        let mut gv1: Vec<bool> = (0..g_count).map(|g| g % 2 == 0).collect();
        let mut gs2 = gs1.clone();
        let mut gz2 = gz1.clone();
        let mut gv2 = gv1.clone();
        let ga = fallback
            .fused_group_screen(
                &x, &r, &starts, &sizes, Some(gkeep), 0.015, &mut gs1, &mut gz1,
                &mut gv1,
            )
            .unwrap();
        let gb = nat
            .fused_group_screen(
                &x, &r, &starts, &sizes, Some(gkeep), 0.015, &mut gs2, &mut gz2,
                &mut gv2,
            )
            .unwrap();
        assert_eq!(ga.strong, gb.strong);
        assert_eq!(ga.safe_size, gb.safe_size);
        assert_eq!(ga.discarded, gb.discarded);
        assert_eq!(ga.cols_scanned, gb.cols_scanned);
        assert_eq!(gs1, gs2);
        assert_eq!(gz1, gz2);
        assert_eq!(gv1, gv2);
    }
}
