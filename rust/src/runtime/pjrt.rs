//! PJRT-backed scan engine: executes the AOT-compiled JAX/Pallas screening
//! kernel from `artifacts/*.hlo.txt`.
//!
//! `make artifacts` lowers the L2 JAX graph (which calls the L1 Pallas
//! kernel under `interpret=True`) to **HLO text** — the interchange format
//! that round-trips through xla_extension 0.5.1 (serialized protos from
//! jax ≥ 0.5 carry 64-bit instruction ids it rejects; the text parser
//! reassigns ids). This engine discovers artifacts named
//!
//! ```text
//! xtrt_pallas_n{N}_p{P}.hlo.txt  (feature-major Pallas kernel — preferred)
//! xtr_pallas_n{N}_p{P}.hlo.txt   (row-major Pallas kernel)
//! xtr_n{N}_p{P}.hlo.txt          (plain-jnp fallback)
//! ```
//!
//! compiles the best one once on the PJRT CPU client, and serves arbitrary
//! `(n, p)` scans by tiling: each call computes the partial sums
//! `Xᵀ_tile · v_tile` for a zero-padded tile; Rust accumulates across row
//! tiles and applies the `1/n` normalization. Padding is exact (zero
//! rows/columns contribute nothing to the dot products).
//!
//! ### Feature gating
//!
//! The real engine needs the `xla` crate, which the offline registry
//! cannot vendor; it compiles only under the **`pjrt` cargo feature** (see
//! `Cargo.toml` for how to point it at a local checkout). Without the
//! feature this module provides a stub whose `load` always returns an
//! [`HssrError::Artifact`], so every call site (CLI `--engine pjrt`,
//! benches, `make_engine`) degrades gracefully to the native pool engine.
//! The fused `ScanEngine` entry points are *not* overridden by either
//! variant: the PJRT engine uses the trait's scan-then-filter defaults.
//!
//! ### §Perf note
//!
//! The original engine used the row-major `(N × P)` tile: filling it from
//! the column-major `DenseMatrix` was a strided scatter (one f64 every
//! `P·8` bytes) that dominated the profile. The **transposed** artifact
//! (`xtrt_*`, feature-major `(P × N)`) turns the fill into one contiguous
//! `copy_from_slice` per feature, and the engine only zeroes the padding
//! tails instead of the whole 8 MiB buffer. See EXPERIMENTS.md §Perf for
//! the before/after.

#[cfg(feature = "pjrt")]
use std::cell::{Cell, RefCell};
#[cfg(feature = "pjrt")]
use std::path::Path;

use super::ScanEngine;
use crate::error::{HssrError, Result};
use crate::linalg::DenseMatrix;

/// Parse `xtr[t][_pallas]_n{N}_p{P}.hlo.txt` → `(transposed, pallas, n, p)`.
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
fn parse_artifact_name(name: &str) -> Option<(bool, bool, usize, usize)> {
    let stem = name.strip_suffix(".hlo.txt")?;
    let (transposed, pallas, rest) = if let Some(r) = stem.strip_prefix("xtrt_pallas_") {
        (true, true, r)
    } else if let Some(r) = stem.strip_prefix("xtr_pallas_") {
        (false, true, r)
    } else if let Some(r) = stem.strip_prefix("xtrt_") {
        (true, false, r)
    } else if let Some(r) = stem.strip_prefix("xtr_") {
        (false, false, r)
    } else {
        return None;
    };
    let mut it = rest.split('_');
    let n = it.next()?.strip_prefix('n')?.parse().ok()?;
    let p = it.next()?.strip_prefix('p')?.parse().ok()?;
    Some((transposed, pallas, n, p))
}

/// One compiled tile executable.
#[cfg(feature = "pjrt")]
struct TileExe {
    n_tile: usize,
    p_tile: usize,
    exe: xla::PjRtLoadedExecutable,
    /// Whether this artifact embeds the Pallas kernel lowering.
    pallas: bool,
    /// Whether the artifact expects the feature-major `(P × N)` layout.
    transposed: bool,
}

/// PJRT scan engine (see module docs).
#[cfg(feature = "pjrt")]
pub struct PjrtEngine {
    client: xla::PjRtClient,
    tile: TileExe,
    /// Reusable tile buffer (row-major `(n_tile, p_tile)` or feature-major
    /// `(p_tile, n_tile)` depending on the artifact).
    scratch: RefCell<Vec<f64>>,
    /// High-water mark of columns written in `scratch` (stale-data guard).
    dirty_cols: Cell<usize>,
}

#[cfg(feature = "pjrt")]
impl PjrtEngine {
    /// Discover and compile artifacts from `dir`. Preference order:
    /// transposed-Pallas, row-major Pallas, plain jnp; larger tiles win ties.
    pub fn load(dir: &str) -> Result<PjrtEngine> {
        let client = xla::PjRtClient::cpu()?;
        let mut best: Option<(bool, bool, usize, usize, std::path::PathBuf)> = None;
        let dir_path = Path::new(dir);
        if !dir_path.is_dir() {
            return Err(HssrError::Artifact(format!(
                "artifact directory '{dir}' not found — run `make artifacts` first"
            )));
        }
        for entry in std::fs::read_dir(dir_path)? {
            let entry = entry?;
            let fname = entry.file_name();
            let Some(name) = fname.to_str() else { continue };
            if let Some((t, pl, n, p)) = parse_artifact_name(name) {
                let better = match &best {
                    None => true,
                    Some((bt, bp, bn, bpp, _)) => (t, pl, n * p) > (*bt, *bp, bn * bpp),
                };
                if better {
                    best = Some((t, pl, n, p, entry.path()));
                }
            }
        }
        let Some((transposed, pallas, n_tile, p_tile, path)) = best else {
            return Err(HssrError::Artifact(format!(
                "no xtr artifacts in '{dir}' — run `make artifacts`"
            )));
        };
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| HssrError::Artifact("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(PjrtEngine {
            client,
            tile: TileExe { n_tile, p_tile, exe, pallas, transposed },
            scratch: RefCell::new(vec![0.0; n_tile * p_tile]),
            dirty_cols: Cell::new(0),
        })
    }

    /// Tile dimensions `(n_tile, p_tile)` of the compiled artifact.
    pub fn tile_shape(&self) -> (usize, usize) {
        (self.tile.n_tile, self.tile.p_tile)
    }

    /// Whether the loaded artifact embeds the Pallas kernel.
    pub fn is_pallas(&self) -> bool {
        self.tile.pallas
    }

    /// Whether the loaded artifact uses the optimized feature-major layout.
    pub fn is_transposed(&self) -> bool {
        self.tile.transposed
    }

    /// Fill the scratch tile with columns `idx` over rows `[i0, i0+rows)`,
    /// zeroing exactly the possibly-stale padding.
    fn fill_tile(&self, x: &DenseMatrix, idx: &[usize], i0: usize, rows: usize) {
        let (nt, pt) = (self.tile.n_tile, self.tile.p_tile);
        let mut buf = self.scratch.borrow_mut();
        if self.tile.transposed {
            // feature-major (P × N): contiguous memcpy per feature.
            for (k, &j) in idx.iter().enumerate() {
                let dst = &mut buf[k * nt..(k + 1) * nt];
                dst[..rows].copy_from_slice(&x.col(j)[i0..i0 + rows]);
                dst[rows..].iter_mut().for_each(|v| *v = 0.0);
            }
            // clear columns written by a previous, wider call
            for k in idx.len()..self.dirty_cols.get() {
                buf[k * nt..(k + 1) * nt].iter_mut().for_each(|v| *v = 0.0);
            }
        } else {
            // row-major (N × P): strided scatter (legacy layout).
            let stale = self.dirty_cols.get().max(idx.len());
            for row in buf.chunks_exact_mut(pt).take(rows) {
                row[..stale].iter_mut().for_each(|v| *v = 0.0);
            }
            for row in buf.chunks_exact_mut(pt).skip(rows) {
                row[..stale].iter_mut().for_each(|v| *v = 0.0);
            }
            for (k, &j) in idx.iter().enumerate() {
                let col = &x.col(j)[i0..i0 + rows];
                for (di, &val) in col.iter().enumerate() {
                    buf[di * pt + k] = val;
                }
            }
        }
        self.dirty_cols.set(idx.len());
    }

    /// Execute one padded tile against a padded `v` device buffer; returns
    /// the `p_tile` partial sums for rows `[i0, i0+rows)`.
    ///
    /// §Perf: inputs go through `buffer_from_host_buffer` + `execute_b`
    /// rather than `Literal` + `execute` — one host copy instead of three
    /// (Literal::vec1, reshape, and the implicit transfer inside execute).
    fn run_tile(
        &self,
        x: &DenseMatrix,
        v_buf: &xla::PjRtBuffer,
        idx: &[usize],
        i0: usize,
        rows: usize,
    ) -> Result<Vec<f64>> {
        let (nt, pt) = (self.tile.n_tile, self.tile.p_tile);
        debug_assert!(rows <= nt && idx.len() <= pt);
        self.fill_tile(x, idx, i0, rows);
        let buf = self.scratch.borrow();
        let dims: [usize; 2] =
            if self.tile.transposed { [pt, nt] } else { [nt, pt] };
        let x_buf = self.client.buffer_from_host_buffer::<f64>(&buf, &dims, None)?;
        drop(buf);
        let result = self.tile.exe.execute_b(&[&x_buf, v_buf])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f64>()?)
    }
}

#[cfg(feature = "pjrt")]
impl ScanEngine for PjrtEngine {
    fn name(&self) -> &'static str {
        match (self.tile.pallas, self.tile.transposed) {
            (true, true) => "pjrt-pallas-t",
            (true, false) => "pjrt-pallas",
            (false, true) => "pjrt-t",
            (false, false) => "pjrt",
        }
    }

    fn scan_subset(
        &self,
        x: &DenseMatrix,
        v: &[f64],
        idx: &[usize],
        out: &mut [f64],
    ) -> Result<()> {
        assert_eq!(idx.len(), out.len());
        let n = x.nrows();
        let inv_n = 1.0 / n as f64;
        let (nt, pt) = (self.tile.n_tile, self.tile.p_tile);
        out.iter_mut().for_each(|o| *o = 0.0);
        let mut i0 = 0;
        while i0 < n {
            let rows = nt.min(n - i0);
            let mut vbuf = vec![0.0f64; nt];
            vbuf[..rows].copy_from_slice(&v[i0..i0 + rows]);
            let v_buf = self.client.buffer_from_host_buffer::<f64>(&vbuf, &[nt], None)?;
            for (chunk_idx, chunk_out) in idx.chunks(pt).zip(out.chunks_mut(pt)) {
                let partial = self.run_tile(x, &v_buf, chunk_idx, i0, rows)?;
                for (o, pv) in chunk_out.iter_mut().zip(&partial) {
                    *o += pv;
                }
            }
            i0 += rows;
        }
        for o in out.iter_mut() {
            *o *= inv_n;
        }
        Ok(())
    }

    fn scan_all(&self, x: &DenseMatrix, v: &[f64], out: &mut [f64]) -> Result<()> {
        let idx: Vec<usize> = (0..x.ncols()).collect();
        self.scan_subset(x, v, &idx, out)
    }
}

/// Stub compiled without the `pjrt` feature: [`PjrtEngine::load`] always
/// fails with an [`HssrError::Artifact`] explaining how to enable the real
/// engine, so callers fall back to [`super::native::NativeEngine`].
#[cfg(not(feature = "pjrt"))]
pub struct PjrtEngine {
    _private: (),
}

#[cfg(not(feature = "pjrt"))]
impl PjrtEngine {
    /// Always fails: the crate was built without the `pjrt` feature.
    pub fn load(dir: &str) -> Result<PjrtEngine> {
        Err(HssrError::Artifact(format!(
            "PJRT engine unavailable: built without the `pjrt` cargo feature \
             (artifact dir '{dir}' ignored); rebuild with --features pjrt and \
             a local `xla` crate checkout"
        )))
    }

    /// Tile dimensions of the compiled artifact (stub: unreachable — `load`
    /// never returns an instance).
    pub fn tile_shape(&self) -> (usize, usize) {
        unreachable!("stub PjrtEngine cannot be constructed")
    }

    /// Whether the loaded artifact embeds the Pallas kernel (stub).
    pub fn is_pallas(&self) -> bool {
        unreachable!("stub PjrtEngine cannot be constructed")
    }

    /// Whether the artifact uses the feature-major layout (stub).
    pub fn is_transposed(&self) -> bool {
        unreachable!("stub PjrtEngine cannot be constructed")
    }
}

#[cfg(not(feature = "pjrt"))]
impl ScanEngine for PjrtEngine {
    fn name(&self) -> &'static str {
        "pjrt-stub"
    }

    fn scan_subset(
        &self,
        _x: &DenseMatrix,
        _v: &[f64],
        _idx: &[usize],
        _out: &mut [f64],
    ) -> Result<()> {
        unreachable!("stub PjrtEngine cannot be constructed")
    }

    fn scan_all(&self, _x: &DenseMatrix, _v: &[f64], _out: &mut [f64]) -> Result<()> {
        unreachable!("stub PjrtEngine cannot be constructed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_name_parsing() {
        assert_eq!(
            parse_artifact_name("xtr_n512_p2048.hlo.txt"),
            Some((false, false, 512, 2048))
        );
        assert_eq!(
            parse_artifact_name("xtr_pallas_n256_p1024.hlo.txt"),
            Some((false, true, 256, 1024))
        );
        assert_eq!(
            parse_artifact_name("xtrt_pallas_n512_p2048.hlo.txt"),
            Some((true, true, 512, 2048))
        );
        assert_eq!(parse_artifact_name("model.hlo.txt"), None);
        assert_eq!(parse_artifact_name("xtr_n512_p2048.bin"), None);
    }

    #[test]
    fn missing_dir_is_artifact_error() {
        // Without the feature, any load is an Artifact error; with it, a
        // missing directory is.
        match PjrtEngine::load("/nonexistent-artifacts") {
            Err(crate::error::HssrError::Artifact(_)) => {}
            Err(other) => panic!("wrong error kind: {other}"),
            Ok(_) => panic!("load should fail on a missing directory"),
        }
    }

    // End-to-end numeric agreement with the native engine is covered by
    // rust/tests/pjrt_engine.rs (requires `make artifacts` + --features pjrt).
}
