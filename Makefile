# hssr — build/verify entry points.
#
#   make verify     tier-1 gate (build + tests) plus fmt/clippy lint + docs
#   make tier1      exactly the tier-1 command the CI driver runs
#   make doc        rustdoc with warnings denied (the CI doc job)
#   make bench      perf probes (emit BENCH_perf.json + BENCH_serve.json
#                   at the repo root)
#   make diskless   the CI test-diskless leg locally: the whole suite with
#                   store-backed fits, a 4 MB cache, and the prefetcher on
#   make artifacts  AOT-lower the JAX/Pallas scan kernels to HLO text
#                   (needs the python toolchain; not required for tier-1)

CARGO_DIR := rust

.PHONY: verify tier1 lint doc bench diskless artifacts

tier1:
	cd $(CARGO_DIR) && cargo build --release && cargo test -q

diskless:
	cd $(CARGO_DIR) && HSSR_ENGINE=ooc HSSR_CACHE_MB=4 HSSR_PREFETCH=1 cargo test -q

lint:
	cd $(CARGO_DIR) && cargo fmt --check
	cd $(CARGO_DIR) && cargo clippy --all-targets -- -D warnings

doc:
	cd $(CARGO_DIR) && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

verify: tier1 lint doc

bench:
	cd $(CARGO_DIR) && cargo bench --bench perf_probe
	cd $(CARGO_DIR) && cargo bench --bench serve_throughput

artifacts:
	python3 python/compile/aot.py
