"""Make `pytest python/tests/` work from the repository root: the test
modules import the `compile` package that lives under python/."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
