//! End-to-end driver — proves all three layers compose on a real workload.
//!
//! Pipeline:
//!   1. generate a GENE-like panel (the paper's §5.1.2(a) regime);
//!   2. load the AOT artifacts (JAX L2 graph embedding the L1 Pallas
//!      kernel, lowered to HLO text by `make artifacts`) into the PJRT
//!      engine — **no Python runs here**;
//!   3. fit the full 100-λ path with every method of Table 2, routing the
//!      screening/KKT scans of one fit through the PJRT engine;
//!   4. verify every method returns the same solution path (Theorem 3.1)
//!      and that native and PJRT engines agree numerically;
//!   5. print the paper-style timing table + speedups and write
//!      bench_out/e2e_pipeline.csv.
//!
//! Run via `make examples` or:
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use hssr::coordinator::report::Table;
use hssr::prelude::*;
use hssr::runtime::{make_engine, EngineKind};
use hssr::solver::path::{fit_lasso_path_with_engine, PathConfig, PathFit};

fn max_beta_diff(a: &PathFit, b: &PathFit) -> f64 {
    let mut worst = 0.0f64;
    for k in 0..a.lambdas.len() {
        let da = a.beta_dense(k);
        let db = b.beta_dense(k);
        for j in 0..da.len() {
            worst = worst.max((da[j] - db[j]).abs());
        }
    }
    worst
}

fn main() -> Result<(), HssrError> {
    // -- 1. workload ------------------------------------------------------
    let (n, p) = (536, 8000); // GENE-like, p scaled for a <1-min demo
    let ds = DataSpec::gene_like(n, p).generate(2024);
    println!("[1/5] dataset {} generated", ds.name);

    // -- 2. AOT artifacts through PJRT -------------------------------------
    let pjrt = match make_engine(EngineKind::Pjrt, "artifacts") {
        Ok(e) => {
            println!("[2/5] PJRT engine loaded ({})", e.name());
            Some(e)
        }
        Err(e) => {
            println!("[2/5] PJRT engine unavailable ({e}); native-only run");
            None
        }
    };

    // -- 3. fit all Table-2 methods ----------------------------------------
    let base = PathConfig::default();
    let mut fits: Vec<(String, PathFit)> = Vec::new();
    for rule in RuleKind::paper_lasso_methods() {
        let cfg = PathConfig { rule, ..base.clone() };
        let fit = fit_lasso_path(&ds, &cfg)?;
        println!(
            "[3/5] {:<10} {:.3}s  (|S| at λ50: {}, scans: {})",
            rule.label(),
            fit.seconds,
            fit.metrics[50].safe_size,
            fit.total_cols_scanned()
        );
        fits.push((rule.label().to_string(), fit));
    }

    // -- 4. cross-validation of solutions + engines -------------------------
    let baseline = &fits[0].1;
    for (name, fit) in &fits[1..] {
        let d = max_beta_diff(baseline, fit);
        assert!(d < 1e-5, "{name} deviates from Basic PCD by {d}");
    }
    println!("[4/5] all methods agree with Basic PCD (Theorem 3.1) ✓");
    if let Some(engine) = &pjrt {
        let cfg = PathConfig { rule: RuleKind::SsrBedpp, n_lambda: 30, ..base.clone() };
        let native_fit = fit_lasso_path(&ds, &cfg)?;
        let pjrt_fit = fit_lasso_path_with_engine(&ds, &cfg, engine.as_ref())?;
        let d = max_beta_diff(&native_fit, &pjrt_fit);
        assert!(d < 1e-6, "pjrt engine deviates by {d}");
        println!(
            "[4/5] PJRT-routed fit matches native (max |Δβ| = {d:.2e}); \
             pjrt path took {:.3}s vs native {:.3}s ✓",
            pjrt_fit.seconds, native_fit.seconds
        );
    }

    // -- 5. report -----------------------------------------------------------
    let basic = fits[0].1.seconds;
    let mut table = Table::new(
        &format!("e2e: lasso path on {} (100 λ values)", ds.name),
        &["Method", "time (s)", "speedup vs Basic PCD", "cols scanned", "KKT checks", "violations"],
    );
    for (name, fit) in &fits {
        table.push_row(vec![
            name.clone(),
            format!("{:.3}", fit.seconds),
            format!("{:.1}x", basic / fit.seconds),
            fit.total_cols_scanned().to_string(),
            fit.total_kkt_checks().to_string(),
            fit.total_violations().to_string(),
        ]);
    }
    table.emit("e2e_pipeline")?;
    println!("[5/5] done — results recorded in EXPERIMENTS.md §E2E");
    Ok(())
}
