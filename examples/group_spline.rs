//! GENE-SPLINE workload (paper §5.2.2b): B-spline basis expansion of a
//! gene-expression-like panel, fitted with the group lasso under the
//! Theorem 4.2 group-BEDPP hybrid rule.
//!
//! ```bash
//! cargo run --release --example group_spline
//! ```

use hssr::data::{bspline, DataSpec};
use hssr::prelude::*;
use hssr::solver::group_path::GroupPathConfig;

fn main() -> Result<(), HssrError> {
    // Scaled-down GENE (the full 536×17,322 runs in the table3 bench).
    let base = DataSpec::gene_like(300, 1200).generate(21);
    println!("base dataset: {}", base.name);
    let ds = bspline::expand_dataset(&base, 5);
    println!(
        "expanded: {} — {} groups, {} columns after orthonormalization",
        ds.name,
        ds.num_groups(),
        ds.p()
    );

    for rule in [RuleKind::BasicPcd, RuleKind::Ssr, RuleKind::SsrBedpp] {
        let cfg = GroupPathConfig { rule, ..GroupPathConfig::default() };
        let fit = fit_group_path(&ds, &cfg)?;
        let label = if rule == RuleKind::BasicPcd { "Basic GD" } else { rule.label() };
        println!(
            "{label:>10}: {:.3}s, {} active groups at λmin, {} group-columns scanned",
            fit.seconds,
            fit.active_groups_at(fit.lambdas.len() - 1, &ds),
            fit.total_cols_scanned(),
        );
    }

    // Back-transform the λmin solution to raw-basis coefficients for one
    // active group (demonstrating the orthonormalization round trip).
    let cfg = GroupPathConfig { rule: RuleKind::SsrBedpp, ..GroupPathConfig::default() };
    let fit = fit_group_path(&ds, &cfg)?;
    let beta = fit.beta_dense(fit.lambdas.len() - 1);
    if let Some(g) = (0..ds.num_groups()).find(|&g| ds.layout.range(g).any(|j| beta[j] != 0.0))
    {
        let t = &ds.back_transforms[g];
        let w_raw = ds.raw_sizes[g];
        let w_new = ds.layout.sizes[g];
        let mut raw = vec![0.0; w_raw];
        for (k, j) in ds.layout.range(g).enumerate() {
            for a in 0..w_raw {
                raw[a] += t[k * w_raw + a] * beta[j];
            }
        }
        println!(
            "group {g}: {} orthonormal coefs → raw B-spline coefs {:?}",
            w_new,
            raw.iter().map(|v| format!("{v:.3}")).collect::<Vec<_>>()
        );
    }
    Ok(())
}
