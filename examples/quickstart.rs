//! Quickstart: fit a lasso path with the paper's headline rule (SSR-BEDPP)
//! on synthetic data and inspect what screening did.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use hssr::prelude::*;

fn main() -> Result<(), HssrError> {
    // 1. A synthetic workload: n = 1000, p = 5000, 20 true features
    //    (the Figure-2 generating model).
    let ds = DataSpec::synthetic(1000, 5000, 20).generate(42);
    println!("dataset: {} ({} × {})", ds.name, ds.n(), ds.p());

    // 2. Fit the full 100-point λ path with hybrid safe-strong screening.
    let cfg = PathConfig { rule: RuleKind::SsrBedpp, ..PathConfig::default() };
    let fit = fit_lasso_path(&ds, &cfg)?;
    println!(
        "fitted {} λ values in {:.3}s — {} columns scanned, {} KKT checks, {} violations",
        fit.lambdas.len(),
        fit.seconds,
        fit.total_cols_scanned(),
        fit.total_kkt_checks(),
        fit.total_violations(),
    );

    // 3. How much did each screening layer discard mid-path?
    let k = fit.lambdas.len() / 2;
    let m = &fit.metrics[k];
    println!(
        "at λ/λmax = {:.2}: safe set {} of {} features, strong set {}, {} nonzero",
        m.lambda / fit.lambda_max,
        m.safe_size,
        ds.p(),
        m.strong_size,
        m.nonzero
    );

    // 4. Support recovery at the end of the path.
    let truth = ds.truth.clone().unwrap_or_default();
    let last = fit.betas.last().unwrap();
    let selected: Vec<usize> = last.iter().map(|&(j, _)| j).collect();
    let hits = truth.iter().filter(|j| selected.contains(j)).count();
    println!(
        "at λmin: selected {} features, recovering {}/{} true features",
        selected.len(),
        hits,
        truth.len()
    );
    Ok(())
}
