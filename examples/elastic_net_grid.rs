//! Elastic net over a grid of mixing weights α — exercising the Theorem 4.1
//! extension of BEDPP. For each α we fit the path twice (SSR vs SSR-BEDPP)
//! and report the screening benefit.
//!
//! ```bash
//! cargo run --release --example elastic_net_grid
//! ```

use hssr::coordinator::report::Table;
use hssr::prelude::*;
use hssr::solver::path::PathConfig;

fn main() -> Result<(), HssrError> {
    let ds = DataSpec::gene_like(400, 4000).generate(7);
    println!("dataset: {}", ds.name);
    let mut table = Table::new(
        "elastic net: SSR vs SSR-BEDPP across α",
        &["α", "SSR time", "SSR-BEDPP time", "speedup", "cols scanned SSR", "cols scanned HSSR", "max |Δβ|"],
    );
    for &alpha in &[1.0, 0.8, 0.5, 0.2] {
        let penalty =
            if alpha >= 1.0 { Penalty::Lasso } else { Penalty::ElasticNet { alpha } };
        let mk = |rule| PathConfig { rule, penalty, ..PathConfig::default() };
        let ssr = fit_lasso_path(&ds, &mk(RuleKind::Ssr))?;
        let hssr = fit_lasso_path(&ds, &mk(RuleKind::SsrBedpp))?;
        // solutions must agree (Theorem 3.1)
        let mut worst = 0.0f64;
        for k in 0..ssr.lambdas.len() {
            let a = ssr.beta_dense(k);
            let b = hssr.beta_dense(k);
            for j in 0..a.len() {
                worst = worst.max((a[j] - b[j]).abs());
            }
        }
        assert!(worst < 1e-5, "solution mismatch at α={alpha}: {worst}");
        table.push_row(vec![
            format!("{alpha:.1}"),
            format!("{:.3}s", ssr.seconds),
            format!("{:.3}s", hssr.seconds),
            format!("{:.2}x", ssr.seconds / hssr.seconds),
            ssr.total_cols_scanned().to_string(),
            hssr.total_cols_scanned().to_string(),
            format!("{worst:.1e}"),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}
