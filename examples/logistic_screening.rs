//! Sparse logistic regression with strong-rule screening — the paper's §6
//! future-work extension, runnable end to end.
//!
//! ```bash
//! cargo run --release --example logistic_screening
//! ```

use hssr::error::HssrError;
use hssr::screening::RuleKind;
use hssr::solver::logistic::{
    deviance, fit_logistic_path, synthetic_logistic, LogisticPathConfig,
};

fn main() -> Result<(), HssrError> {
    let (x, y, truth) = synthetic_logistic(600, 3_000, 8, 31);
    println!(
        "logistic workload: n={}, p={}, {} true features, base rate {:.2}",
        x.nrows(),
        x.ncols(),
        truth.len(),
        y.iter().sum::<f64>() / y.len() as f64
    );
    let mut basic_time = 0.0;
    for rule in [
        RuleKind::BasicPcd,
        RuleKind::ActiveCycling,
        RuleKind::Ssr,
        RuleKind::SsrGapSafe,
    ] {
        let cfg = LogisticPathConfig { rule, n_lambda: 50, ..Default::default() };
        let fit = fit_logistic_path(&x, &y, &cfg)?;
        if rule == RuleKind::BasicPcd {
            basic_time = fit.seconds;
        }
        let k_last = fit.lambdas.len() - 1;
        let probs = fit.predict_proba(&x, k_last);
        let sel: Vec<usize> = fit.betas[k_last].iter().map(|&(j, _)| j).collect();
        let hits = truth.iter().filter(|j| sel.contains(j)).count();
        println!(
            "{:>9}: {:.3}s ({:.1}x), deviance {:.4}, {} selected ({hits}/{} true), {} violations",
            rule.label(),
            fit.seconds,
            basic_time / fit.seconds,
            deviance(&y, &probs),
            sel.len(),
            truth.len(),
            fit.metrics.iter().map(|m| m.violations).sum::<usize>(),
        );
    }
    println!(
        "\n(The quadratic-loss safe rules do not port to the logistic dual —\n\
         exactly the open problem §6 of the paper leaves; SSR + KKT checking does.)"
    );
    Ok(())
}
