//! Out-of-core memory traffic — the §3.2.3 "memory efficiency" claim,
//! measured as **actual disk reads** against the real column store.
//!
//! biglasso's selling point is lasso fitting on data too big for RAM
//! (memory-mapped big.matrix). This example reproduces that regime for
//! real: the dataset is spilled to an `HSSRSTOR1` column store on disk,
//! and each strategy's path runs with every screening/KKT scan served by
//! the `OocEngine` through an LRU chunk cache whose budget is a small
//! fraction of the matrix footprint. The table reports measured chunk
//! loads, bytes read from disk, cache hits, and peak resident bytes —
//! cross-checked against the path's own `cols_scanned` accounting. HSSR
//! touches only the safe set, so its read traffic collapses while
//! SSR must stream the whole matrix at every λ.
//!
//! ```bash
//! cargo run --release --example out_of_core
//! HSSR_CACHE_MB=2 cargo run --release --example out_of_core   # harsher budget
//! ```

use hssr::coordinator::metrics::{ooc_scan_traffic, ooc_traffic_table};
use hssr::data::store;
use hssr::prelude::*;
use hssr::solver::path::PathConfig;

fn main() -> Result<(), HssrError> {
    let ds = DataSpec::gene_like(300, 8000).generate(9);
    let chunk_cols = 256;
    let matrix_mb = (ds.n() * ds.p() * 8) as f64 / 1e6;
    // Budget ≪ matrix: ~8 chunks resident out of ~32.
    let budget = store::cache_budget_bytes().min((8 * chunk_cols * ds.n() * 8).max(1 << 20));
    println!(
        "dataset: {} ({matrix_mb:.1} MB as f64) → disk store, {chunk_cols}-col chunks, \
         cache budget {:.1} MB",
        ds.name,
        budget as f64 / 1e6
    );

    let cfg = PathConfig::default();
    let rows = ooc_scan_traffic(
        &ds,
        &cfg,
        chunk_cols,
        budget,
        &[RuleKind::Ssr, RuleKind::SsrDome, RuleKind::SsrBedpp, RuleKind::SsrGapSafe],
    )?;
    let table = ooc_traffic_table(
        "out-of-core disk traffic over the full path (100 λ), measured",
        &rows,
    );
    println!("{}", table.render());
    println!(
        "(SSR-GapSafe's in-rule scans are engine-routed, so its column count is fully\n\
         measured; SEDPP's remain internal — see benches/ablation_scans for its\n\
         analytic accounting. Convert your own data with `hssr convert data.csv\n\
         data.store` and fit it with `hssr fit --data store --path data.store\n\
         --engine ooc`.)"
    );
    Ok(())
}
