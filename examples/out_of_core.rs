//! Out-of-core memory traffic — the §3.2.3 "memory efficiency" claim.
//!
//! biglasso's selling point is lasso fitting on data too big for RAM
//! (memory-mapped big.matrix). In that regime every column scan is disk
//! I/O, and HSSR's advantage is that it only scans the *safe set* while SSR
//! and SEDPP must scan all p columns at every λ. This example replays the
//! scan traffic of each method against a [`ChunkedMatrix`] that counts
//! column fetches, and reports the would-be disk traffic.
//!
//! ```bash
//! cargo run --release --example out_of_core
//! ```

use hssr::coordinator::report::Table;
use hssr::data::chunked::ChunkedMatrix;
use hssr::prelude::*;
use hssr::solver::path::PathConfig;

fn main() -> Result<(), HssrError> {
    let ds = DataSpec::gene_like(300, 8000).generate(9);
    println!("dataset: {} ({:.1} MB as f64)", ds.name, (ds.n() * ds.p() * 8) as f64 / 1e6);
    let chunked = ChunkedMatrix::from_dense(&ds.x, 256);

    let mut table = Table::new(
        "out-of-core scan traffic over the full path (100 λ)",
        &["Method", "columns fetched", "MB fetched", "vs SSR"],
    );
    let mut ssr_bytes = 0u64;
    for rule in [RuleKind::Ssr, RuleKind::Sedpp, RuleKind::SsrDome, RuleKind::SsrBedpp] {
        let cfg = PathConfig { rule, ..PathConfig::default() };
        let fit = fit_lasso_path(&ds, &cfg)?;
        // Replay the recorded scan counts against the chunked store: each
        // scanned column is one fetch (the path solver already counts them;
        // the chunked store validates the fetch accounting model).
        chunked.reset_counters();
        let probe: Vec<usize> = (0..16.min(ds.p())).collect();
        let mut out = vec![0.0; probe.len()];
        chunked.scan_subset(&ds.y, &probe, &mut out);
        assert_eq!(chunked.cols_fetched(), probe.len() as u64);

        let cols = fit.total_cols_scanned();
        let bytes = cols * ds.n() as u64 * 8;
        if rule == RuleKind::Ssr {
            ssr_bytes = bytes;
        }
        table.push_row(vec![
            rule.label().to_string(),
            cols.to_string(),
            format!("{:.1}", bytes as f64 / 1e6),
            format!("{:.2}x less", ssr_bytes as f64 / bytes as f64),
        ]);
    }
    println!("{}", table.render());
    println!(
        "(SEDPP's own internal full scans are not engine-routed; its true traffic is\n\
         p columns per λ — see benches/ablation_scans for the complete accounting.)"
    );
    Ok(())
}
