//! Out-of-core memory traffic — the §3.2.3 "memory efficiency" claim,
//! **measured** rather than replayed.
//!
//! biglasso's selling point is lasso fitting on data too big for RAM
//! (memory-mapped big.matrix). In that regime every column scan is disk
//! I/O, and HSSR's advantage is that it only scans the *safe set* while
//! SSR and SEDPP must scan all p columns at every λ. Here the unified path
//! driver runs with every screening/KKT scan dispatched through a counting
//! `ChunkedScanEngine` over a chunked column store
//! (`hssr::coordinator::metrics::scan_traffic`), so the table reports
//! *actual* column fetches and chunk faults, cross-checked against the
//! path's own `cols_scanned` accounting.
//!
//! ```bash
//! cargo run --release --example out_of_core
//! ```

use hssr::coordinator::metrics::{scan_traffic, scan_traffic_table};
use hssr::prelude::*;
use hssr::solver::path::PathConfig;

fn main() -> Result<(), HssrError> {
    let ds = DataSpec::gene_like(300, 8000).generate(9);
    println!(
        "dataset: {} ({:.1} MB as f64), chunk = 256 columns",
        ds.name,
        (ds.n() * ds.p() * 8) as f64 / 1e6
    );

    let cfg = PathConfig::default();
    let rows = scan_traffic(
        &ds,
        &cfg,
        256,
        &[RuleKind::Ssr, RuleKind::Sedpp, RuleKind::SsrDome, RuleKind::SsrBedpp],
    )?;
    let table = scan_traffic_table(
        "out-of-core scan traffic over the full path (100 λ), measured",
        &rows,
    );
    println!("{}", table.render());
    println!(
        "(SEDPP's own internal full scans are not engine-routed; its true traffic is\n\
         p columns per λ — see benches/ablation_scans for the complete accounting.)"
    );
    Ok(())
}
