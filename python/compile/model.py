"""L2 — the JAX compute graphs behind the Rust coordinator's hot path.

Two exported computations (see ``aot.py`` for the AOT lowering):

* ``screen_scan``        — ``z = Xᵀv`` over a tile, via the L1 Pallas
                           kernel (``kernels.xtr``). This is the per-λ hot
                           spot of SSR/SEDPP screening and KKT checking.
* ``screen_scan_jnp``    — the same graph with a plain ``dot_general``
                           instead of the Pallas kernel (ablation baseline).
* ``bedpp_stats``        — the one-time BEDPP precompute: ``Xᵀy``, the
                           argmax column's correlations ``Xᵀx*``, and
                           ``‖y‖²`` (Theorem 2.1's constants), fused into a
                           single graph so XLA shares the ``Xᵀy`` product.

All graphs are pure functions of their tile inputs: no Python state, no
host callbacks — a requirement for the AOT path (Python never runs at
request time).
"""

import jax.numpy as jnp

from .kernels import ref, xtr


def screen_scan(x, v):
    """``Xᵀ·v`` via the Pallas kernel.

    Block sizes adapt to the tile: the default MXU-shaped blocks when the
    input is a multiple of them, else one block per axis (small tiles only
    occur in tests; AOT always compiles full-size tiles).
    """
    n, p = x.shape
    n_blk = xtr.N_BLK if n % xtr.N_BLK == 0 else n
    p_blk = xtr.P_BLK if p % xtr.P_BLK == 0 else p
    return (xtr.xtr(x, v, n_blk=n_blk, p_blk=p_blk),)


def screen_scan_jnp(x, v):
    """``Xᵀ·v`` via plain jnp (XLA fuses this into one dot_general)."""
    return (ref.xtr_ref(x, v),)


def screen_scan_t(xt, v):
    """``Xᵀ·v`` from a feature-major tile (see ``kernels.xtr.xtr_t``)."""
    p, n = xt.shape
    n_blk = xtr.N_BLK if n % xtr.N_BLK == 0 else n
    p_blk = xtr.P_BLK if p % xtr.P_BLK == 0 else p
    return (xtr.xtr_t(xt, v, n_blk=n_blk, p_blk=p_blk),)


def bedpp_stats(x, y):
    """BEDPP precompute graph — Theorem 2.1's per-fit constants."""
    xty, xtx_star, y_sq = ref.bedpp_stats_ref(x, y)
    return xty, xtx_star, jnp.reshape(y_sq, (1,))
