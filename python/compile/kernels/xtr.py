"""L1 — the Pallas screening-scan kernel.

The compute hot-spot of every screening rule is the scan ``z = Xᵀr`` over a
tile of features. On TPU this is a reduction over the n axis feeding the
MXU; the canonical Pallas shape is a 2-D grid over ``(p_tiles, n_tiles)``
with the output block revisited along the n axis (accumulate-in-VMEM
pattern):

* ``x`` block: ``(N_BLK, P_BLK)`` in VMEM — with the default
  ``N_BLK=256, P_BLK=512`` and f32 that is 512 KiB, comfortably inside a
  TPU core's ~16 MiB VMEM with double-buffering headroom;
* ``v`` block: ``(N_BLK,)`` — re-fetched per p tile (tiny);
* ``o`` block: ``(P_BLK,)`` accumulator — lives across the n-axis grid
  steps of the same p tile (grid iteration order makes the n axis minor).

The block matvec ``x.Tᵀ·v`` lowers to a ``dot_general`` contraction the
Mosaic compiler maps onto the MXU. See DESIGN.md §Hardware-Adaptation for
the CPU/GPU→TPU mapping rationale.

NOTE: kernels are lowered with ``interpret=True`` throughout — the CPU PJRT
plugin cannot execute Mosaic custom-calls (see /opt/xla-example/README.md);
real-TPU performance is *estimated* from the VMEM/MXU structure above and
recorded in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile shape (see module docs). Overridable for block-shape sweeps
# (the §Perf pass tunes the interpret-mode grid-step count; real-TPU VMEM
# budgeting is checked by test_vmem_budget).
import os

# §Perf: one grid step per (512, 2048) AOT tile — the interpret-mode grid
# loop dominated CPU execution (24.4 → 7.1 ms/scan on the probe when the
# block covers the tile). On real TPU this block is 4 MiB of VMEM in f32
# (8.4 MiB double-buffered) — inside the ~16 MiB budget; smaller MXU-shaped
# blocks remain available through the explicit n_blk/p_blk arguments.
N_BLK = int(os.environ.get("HSSR_N_BLK", 512))
P_BLK = int(os.environ.get("HSSR_P_BLK", 2048))


def _xtr_kernel(x_ref, v_ref, o_ref):
    """One grid step: accumulate the partial products of an (n, p) block."""
    # Zero the accumulator on the first visit along the n axis.
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    v = v_ref[...]
    # (N_BLK, P_BLK)ᵀ · (N_BLK,) — a dot_general contraction (MXU-shaped).
    o_ref[...] += jnp.dot(x.T, v, precision="highest")


@functools.partial(jax.jit, static_argnames=("n_blk", "p_blk"))
def xtr(x, v, *, n_blk=N_BLK, p_blk=P_BLK):
    """Tiled Pallas evaluation of ``Xᵀ·v`` (un-normalized).

    Shapes must be multiples of the block shape; the AOT path always
    compiles for exact tile multiples and Rust pads the edges with zeros
    (which contribute nothing to the dot products).
    """
    n, p = x.shape
    if n % n_blk or p % p_blk:
        raise ValueError(f"shape {(n, p)} not a multiple of block {(n_blk, p_blk)}")
    grid = (p // p_blk, n // n_blk)
    return pl.pallas_call(
        _xtr_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_blk, p_blk), lambda i, j: (j, i)),
            pl.BlockSpec((n_blk,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((p_blk,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), x.dtype),
        interpret=True,
    )(x, v)


def _xtrt_kernel(xt_ref, v_ref, o_ref):
    """Transposed-layout grid step: xt block is (P_BLK, N_BLK)."""
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(xt_ref[...], v_ref[...], precision="highest")


@functools.partial(jax.jit, static_argnames=("n_blk", "p_blk"))
def xtr_t(xt, v, *, n_blk=N_BLK, p_blk=P_BLK):
    """Tiled Pallas evaluation of ``Xᵀ·v`` from a pre-transposed tile.

    ``xt`` has shape ``(p, n)`` — feature-major. The Rust engine prefers
    this layout because filling the tile from its column-major matrix is a
    contiguous ``memcpy`` per feature instead of a strided scatter (§Perf:
    the fill dominated the row-major path's runtime).
    """
    p, n = xt.shape
    if n % n_blk or p % p_blk:
        raise ValueError(f"shape {(p, n)} not a multiple of block {(p_blk, n_blk)}")
    grid = (p // p_blk, n // n_blk)
    return pl.pallas_call(
        _xtrt_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((p_blk, n_blk), lambda i, j: (i, j)),
            pl.BlockSpec((n_blk,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((p_blk,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), xt.dtype),
        interpret=True,
    )(xt, v)


def vmem_bytes(n_blk=N_BLK, p_blk=P_BLK, dtype_bytes=4):
    """Estimated VMEM footprint of one grid step (x block + v + o + double
    buffering of the x stream). Used by the DESIGN.md roofline estimate."""
    x_block = n_blk * p_blk * dtype_bytes
    v_block = n_blk * dtype_bytes
    o_block = p_blk * dtype_bytes
    return 2 * x_block + v_block + o_block  # 2x for double buffering
