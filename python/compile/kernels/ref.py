"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: every Pallas kernel in this package
must match its oracle to float tolerance across the hypothesis shape/dtype
sweep in ``python/tests/test_kernel.py``.
"""

import jax.numpy as jnp


def xtr_ref(x, v):
    """Screening-scan oracle: column-wise dot products ``Xᵀ·v``.

    Args:
      x: ``(n, p)`` design tile.
      v: ``(n,)`` residual tile.

    Returns:
      ``(p,)`` vector of un-normalized correlations (the 1/n scaling is
      applied by the Rust caller, which knows the true — unpadded — n).
    """
    return jnp.dot(x.T, v, precision="highest")


def bedpp_stats_ref(x, y):
    """Oracle for the BEDPP precompute graph.

    Returns ``(xty, xtx_star, y_sq)`` where ``star = argmax_j |x_jᵀy|`` —
    exactly the quantities ``SafeContext::build`` holds on the Rust side.
    """
    xty = jnp.dot(x.T, y, precision="highest")
    star = jnp.argmax(jnp.abs(xty))
    xtx_star = jnp.dot(x.T, x[:, star], precision="highest")
    y_sq = jnp.dot(y, y, precision="highest")
    return xty, xtx_star, y_sq
