"""Pallas kernels (L1) and their pure-jnp oracles."""
