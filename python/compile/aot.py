"""AOT lowering: JAX/Pallas graphs → HLO **text** artifacts for the Rust
runtime.

Interchange format is HLO text, not a serialized ``HloModuleProto``: jax
≥ 0.5 emits protos with 64-bit instruction ids which the published ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and the smoke-verified ``load_hlo`` reference).

Artifacts written (all f64; the Rust side scans f64 matrices):

* ``xtr_pallas_n{N}_p{P}.hlo.txt`` — L2 graph calling the L1 Pallas kernel
  (the paper stack; preferred by the Rust engine).
* ``xtr_n{N}_p{P}.hlo.txt``        — plain-jnp variant (engine ablation).
* ``bedpp_stats_n{N}_p{P}.hlo.txt``— BEDPP precompute graph.

Usage: ``python -m compile.aot --out-dir ../artifacts [--n 512] [--p 2048]``
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(fn, *args):
    """Lower a jitted function to HLO text via StableHLO → XlaComputation."""
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write(path, text):
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {len(text):>9} chars  {path}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--n", type=int, default=512, help="row-tile size")
    ap.add_argument("--p", type=int, default=2048, help="column-tile size")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    n, p = args.n, args.p

    x_spec = jax.ShapeDtypeStruct((n, p), jnp.float64)
    v_spec = jax.ShapeDtypeStruct((n,), jnp.float64)

    write(
        os.path.join(args.out_dir, f"xtr_pallas_n{n}_p{p}.hlo.txt"),
        to_hlo_text(model.screen_scan, x_spec, v_spec),
    )
    xt_spec = jax.ShapeDtypeStruct((p, n), jnp.float64)
    write(
        os.path.join(args.out_dir, f"xtrt_pallas_n{n}_p{p}.hlo.txt"),
        to_hlo_text(model.screen_scan_t, xt_spec, v_spec),
    )
    write(
        os.path.join(args.out_dir, f"xtr_n{n}_p{p}.hlo.txt"),
        to_hlo_text(model.screen_scan_jnp, x_spec, v_spec),
    )
    write(
        os.path.join(args.out_dir, f"bedpp_stats_n{n}_p{p}.hlo.txt"),
        to_hlo_text(model.bedpp_stats, x_spec, v_spec),
    )


if __name__ == "__main__":
    main()
