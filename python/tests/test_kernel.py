"""L1 correctness: the Pallas screening-scan kernel vs the pure-jnp oracle,
swept over shapes and dtypes with hypothesis."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, xtr

# Block-shape divisors we exercise (kernel requires tile multiples).
BLOCKS = [(8, 16), (16, 32), (32, 64)]


def _tolerance(dtype):
    return 1e-4 if dtype == np.float32 else 1e-10


@settings(max_examples=25, deadline=None)
@given(
    n_tiles=st.integers(1, 4),
    p_tiles=st.integers(1, 4),
    block=st.sampled_from(BLOCKS),
    dtype=st.sampled_from([np.float32, np.float64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_xtr_matches_ref_across_shapes(n_tiles, p_tiles, block, dtype, seed):
    n_blk, p_blk = block
    n, p = n_tiles * n_blk, p_tiles * p_blk
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, p)).astype(dtype))
    v = jnp.asarray(rng.normal(size=(n,)).astype(dtype))
    got = xtr.xtr(x, v, n_blk=n_blk, p_blk=p_blk)
    want = ref.xtr_ref(x, v)
    assert got.dtype == want.dtype
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=_tolerance(dtype) * max(1.0, n**0.5)
    )


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_xtr_zero_vector_gives_zero(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(32, 64)))
    z = xtr.xtr(x, jnp.zeros(32), n_blk=16, p_blk=32)
    np.testing.assert_allclose(np.asarray(z), 0.0)


def test_xtr_default_blocks():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(xtr.N_BLK * 2, xtr.P_BLK)))
    v = jnp.asarray(rng.normal(size=(xtr.N_BLK * 2,)))
    np.testing.assert_allclose(
        np.asarray(xtr.xtr(x, v)), np.asarray(ref.xtr_ref(x, v)), atol=1e-9
    )


def test_xtr_rejects_non_multiple_shapes():
    x = jnp.zeros((100, 100))
    with pytest.raises(ValueError, match="not a multiple"):
        xtr.xtr(x, jnp.zeros(100))


def test_padding_is_exact():
    """Zero-padding rows/cols must not change the unpadded results — this is
    the invariant the Rust tiler relies on."""
    rng = np.random.default_rng(11)
    n, p = 40, 48
    x = rng.normal(size=(n, p))
    v = rng.normal(size=(n,))
    xp = np.zeros((64, 64))
    xp[:n, :p] = x
    vp = np.zeros(64)
    vp[:n] = v
    got = np.asarray(xtr.xtr(jnp.asarray(xp), jnp.asarray(vp), n_blk=32, p_blk=32))
    want = np.asarray(ref.xtr_ref(jnp.asarray(x), jnp.asarray(v)))
    np.testing.assert_allclose(got[:p], want, atol=1e-10)
    np.testing.assert_allclose(got[p:], 0.0)


def test_vmem_budget():
    """Structural perf check: the default tile must fit a TPU core's VMEM
    (DESIGN.md §Hardware-Adaptation; f32 on real TPU)."""
    assert xtr.vmem_bytes() < 12 * 2**20  # < 12 MiB of ~16 MiB
