"""AOT pipeline: HLO-text artifacts are produced, well-formed, and
deterministic."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
PYDIR = os.path.join(REPO, "python")


def run_aot(out_dir, n=16, p=32):
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", out_dir, "--n", str(n), "--p", str(p)],
        cwd=PYDIR,
        check=True,
        capture_output=True,
    )


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    run_aot(str(out))
    return out


def test_all_artifacts_written(artifacts):
    names = sorted(os.listdir(artifacts))
    assert names == [
        "bedpp_stats_n16_p32.hlo.txt",
        "xtr_n16_p32.hlo.txt",
        "xtr_pallas_n16_p32.hlo.txt",
        "xtrt_pallas_n16_p32.hlo.txt",
    ]


def test_artifacts_are_hlo_text(artifacts):
    for name in os.listdir(artifacts):
        body = (artifacts / name).read_text()
        assert body.startswith("HloModule"), f"{name} is not HLO text"
        assert "ENTRY" in body
        # f64 interchange (the Rust scanner is f64)
        assert "f64" in body, f"{name} lost the f64 dtype"


def test_pallas_artifact_differs_from_jnp(artifacts):
    """The Pallas lowering (interpret mode) produces a structurally richer
    module than the single fused dot of the jnp variant."""
    pallas = (artifacts / "xtr_pallas_n16_p32.hlo.txt").read_text()
    plain = (artifacts / "xtr_n16_p32.hlo.txt").read_text()
    assert len(pallas) > len(plain)
    assert "dot" in plain


def test_lowering_is_deterministic(tmp_path):
    a = tmp_path / "a"
    b = tmp_path / "b"
    run_aot(str(a))
    run_aot(str(b))
    for name in os.listdir(a):
        assert (a / name).read_text() == (b / name).read_text(), name
