"""L2 correctness: the exported compute graphs vs numpy."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_screen_scan_variants_agree(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64, 128)))
    v = jnp.asarray(rng.normal(size=(64,)))
    (pallas_out,) = model.screen_scan(x, v)
    (jnp_out,) = model.screen_scan_jnp(x, v)
    np.testing.assert_allclose(np.asarray(pallas_out), np.asarray(jnp_out), atol=1e-10)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_bedpp_stats_vs_numpy(seed):
    rng = np.random.default_rng(seed)
    x_np = rng.normal(size=(50, 30))
    y_np = rng.normal(size=(50,))
    xty, xtx_star, y_sq = model.bedpp_stats(jnp.asarray(x_np), jnp.asarray(y_np))
    xty_np = x_np.T @ y_np
    star = int(np.argmax(np.abs(xty_np)))
    np.testing.assert_allclose(np.asarray(xty), xty_np, atol=1e-10)
    np.testing.assert_allclose(np.asarray(xtx_star), x_np.T @ x_np[:, star], atol=1e-10)
    np.testing.assert_allclose(float(y_sq[0]), y_np @ y_np, atol=1e-10)


def test_graphs_are_pure_and_jittable():
    """AOT prerequisite: lowering must succeed with abstract inputs only."""
    x = jax.ShapeDtypeStruct((64, 128), jnp.float64)
    v = jax.ShapeDtypeStruct((64,), jnp.float64)
    for fn in (model.screen_scan, model.screen_scan_jnp, model.bedpp_stats):
        lowered = jax.jit(fn).lower(x, v)
        assert "stablehlo" in str(lowered.compiler_ir("stablehlo"))
